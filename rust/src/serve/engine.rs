//! The serving event loop: open-loop arrivals → continuous batches →
//! placement-aware routing → simulated service on the cluster model.
//!
//! Every iteration the engine (1) feeds arrivals that have occurred by
//! the simulated clock into the batcher, (2) sheds dead queued work,
//! (3) assembles a continuous batch, (4) routes it for real through the
//! gating zoo (identical routing to the training pipeline), (5) charges
//! service time analytically — gate/layout/expert on the
//! [`GpuModel`] roofline, AllToAll on the [`crate::cluster::NetworkModel`]
//! under the schedule the router picked — and (6) advances the clock by
//! that service time. Requests that finish are timed against their SLO.
//! The whole loop is deterministic for a given [`ServeConfig`].

use crate::cluster::GpuModel;
use crate::config::{ClusterConfig, MoeConfig};
use crate::error::Result;
use crate::fault::{FaultPlan, StepFaults};
use crate::comm::hier_ragged::hier_leg_wire_bytes;
use crate::comm::ragged::split_wire_bytes;
use crate::comm::schedule::{transpose_counts, Schedule};
use crate::comm::{WirePrecision, F32_BYTES_F};
use crate::moe::{CommImpl, StepReport};
use crate::obs::trace;
use crate::pipeline::{ChunkChoice, StagePlan};
use crate::placement::PlacementPolicy;
use crate::serve::router::{CommChoice, PlacementRouter, RouteDecision};
use crate::serve::scheduler::{ContinuousBatcher, SchedulerConfig};
use crate::serve::slo::{SloReport, SloTracker};
use crate::serve::workload::{ArrivalProcess, Request, WorkloadGen};
use crate::tensor::Tensor;
use crate::util::rng::{Rng, Zipf};

/// Full configuration of one serving run.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub moe: MoeConfig,
    pub cluster: ClusterConfig,
    pub gpu: GpuModel,
    pub process: ArrivalProcess,
    pub comm: CommChoice,
    /// Exchange chunking for comm/compute overlap (`Auto` = picked per
    /// batch from its traffic matrix, like the training pipeline).
    pub chunks: ChunkChoice,
    /// Score and charge the hierarchical schedule with top-k token
    /// dedup (mirrors the training side's `MoeLayerOptions::dedup`;
    /// default on).
    pub dedup: bool,
    /// Wire element format batches are scored and charged at (mirrors
    /// the training side's `MoeLayerOptions::wire`; default f32).
    pub wire: WirePrecision,
    /// Per-request latency SLO, seconds.
    pub slo: f64,
    /// Simulated seconds of offered traffic.
    pub duration: f64,
    pub min_tokens: usize,
    pub max_tokens: usize,
    /// Max tokens one request contributes per iteration.
    pub chunk_tokens: usize,
    pub max_queue: usize,
    /// Embedding vocabulary for synthetic token content.
    pub vocab: usize,
    pub seed: u64,
    /// Ranks down from the start: routed around from the first batch.
    pub dead_ranks: Vec<usize>,
    /// Deterministic fault-injection schedule, keyed by batch index
    /// (empty = healthy run).
    pub faults: FaultPlan,
    /// Placement policy. `Static` serves the contiguous layout as-is;
    /// `Adaptive` watches the router's EWMA load and replicates the
    /// hottest expert onto the least-loaded rank when it runs
    /// persistently above `replicate_factor` × the mean.
    pub placement: PlacementPolicy,
    /// Batches between adaptive replication checks (0 disables them).
    pub placement_every: usize,
    /// Hotness threshold for adaptive replication, as a multiple of the
    /// mean per-expert EWMA load.
    pub replicate_factor: f64,
    /// Explicit `(expert, rank)` replicas installed before the first
    /// batch (operator-pinned hot experts).
    pub replicas: Vec<(usize, usize)>,
}

impl ServeConfig {
    /// CPU-friendly defaults: paper expert count at reduced width, the
    /// commodity 2×8 cluster, 2000 req/s Poisson traffic, 50 ms SLO.
    pub fn default_run() -> ServeConfig {
        ServeConfig {
            moe: MoeConfig {
                num_experts: 16,
                d_model: 64,
                ffn_hidden: 128,
                capacity_factor: 1.25,
                gate: crate::config::GateKind::Switch,
            },
            cluster: ClusterConfig::commodity(2),
            gpu: GpuModel::titan_rtx(),
            process: ArrivalProcess::Poisson { rate: 2000.0 },
            comm: CommChoice::Auto,
            chunks: ChunkChoice::Auto,
            dedup: true,
            wire: WirePrecision::F32,
            slo: 0.05,
            duration: 2.0,
            min_tokens: 8,
            max_tokens: 64,
            chunk_tokens: 64,
            max_queue: 4096,
            vocab: 1024,
            seed: 0,
            dead_ranks: Vec::new(),
            faults: FaultPlan::none(),
            placement: PlacementPolicy::Static,
            placement_every: 32,
            replicate_factor: 2.0,
            replicas: Vec::new(),
        }
    }
}

/// Largest per-iteration token budget satisfying both admission
/// budgets: the **expert-capacity budget** (at most 256 rows per expert
/// per iteration, bounding the dispatch buffers) and the **latency
/// budget** (estimated service time of one iteration at most half the
/// SLO, leaving headroom for queueing). Doubling search from the world
/// size.
fn max_tokens_under_budgets(cfg: &ServeConfig, router: &PlacementRouter) -> usize {
    let hard_cap = cfg.moe.num_experts * 256;
    let floor = cfg.cluster.world().max(16).min(hard_cap);
    let mut best = floor;
    while best * 2 <= hard_cap
        && service_estimate_for(cfg, router, best * 2) <= cfg.slo * 0.5
    {
        best *= 2;
    }
    best
}

/// Uniform-routing service estimate behind [`ServeEngine::service_estimate`].
/// Charges the same chunked critical path real iterations are charged
/// (the full [`StagePlan`] decision on the uniform traffic matrix), so
/// the admission budget reaches the throughput the overlap actually
/// buys instead of sizing against the pre-overlap sum of phases.
fn service_estimate_for(cfg: &ServeConfig, router: &PlacementRouter, tokens: usize) -> f64 {
    let w = cfg.cluster.world();
    let k = router.gate.k();
    let per = tokens.div_ceil(w);
    let kept_per_pair = (per * k).div_ceil(w);
    let counts = vec![vec![kept_per_pair; w]; w];
    let row_bytes = cfg.moe.d_model * cfg.wire.elem_bytes();
    let (gate, layout, expert, reverse) =
        phase_times_for(cfg, k, per, per * k, router.placement().max_hosted());
    // Uniform routing: compute splits evenly across destination ranks.
    let compute_per_rank = vec![expert / w as f64; w];
    let (_, overlap) = StagePlan::pick(
        &router.net,
        &counts,
        row_bytes,
        cfg.comm,
        cfg.chunks,
        &compute_per_rank,
        None,
        false,
    );
    gate + layout + overlap.critical_path + reverse
}

/// Roofline times of the per-rank compute phases — `(gate, layout,
/// expert, reverse_layout)`. `experts_per_rank` is the busiest rank's
/// hosted-expert count (exceeds the nominal E/W under elastic remap).
fn phase_times_for(
    cfg: &ServeConfig,
    gate_k: usize,
    shard_tokens: usize,
    rank_rows: usize,
    experts_per_rank: usize,
) -> (f64, f64, f64, f64) {
    let gpu = &cfg.gpu;
    let d = cfg.moe.d_model as f64;
    let e = cfg.moe.num_experts as f64;
    let h = cfg.moe.ffn_hidden as f64;
    let k = gate_k as f64;
    let t = shard_tokens as f64;
    let rows = rank_rows as f64;
    let gate = gpu.kernel_time(2.0 * t * d * e, t * (d + e) * F32_BYTES_F, 1)
        + gpu.memory_time(t * e * F32_BYTES_F, 3);
    let layout = gpu.memory_time(2.0 * t * k * d * F32_BYTES_F, 1);
    let experts_per_rank = experts_per_rank.max(1);
    let expert = gpu.kernel_time(
        4.0 * rows * d * h,
        rows * (d + h) * F32_BYTES_F,
        2 * experts_per_rank,
    );
    let reverse = gpu.memory_time(2.0 * t * k * d * F32_BYTES_F, 1);
    (gate, layout, expert, reverse)
}

/// The serving engine (see module docs).
pub struct ServeEngine {
    pub cfg: ServeConfig,
    pub router: PlacementRouter,
    batcher: ContinuousBatcher,
    embedding: Tensor,
    token_dist: Zipf,
    rng: Rng,
    clock: f64,
    step: u64,
    /// Ranks currently routed around (initial dead + kills so far).
    dead: Vec<usize>,
    /// Replica copies installed by the adaptive policy this run.
    pub replications: usize,
}

impl ServeEngine {
    pub fn new(cfg: ServeConfig) -> Result<ServeEngine> {
        let w = cfg.cluster.world();
        let mut dead = cfg.dead_ranks.clone();
        dead.extend(cfg.faults.initial_dead());
        dead.sort_unstable();
        dead.dedup();
        for &r in &dead {
            if r >= w {
                return Err(crate::fault_err!(
                    "dead rank {r} is outside the world of {w} ranks"
                ));
            }
        }
        if !dead.is_empty() && dead.len() >= w {
            return Err(crate::fault_err!(
                "all {w} ranks are marked dead — nothing left to serve on"
            ));
        }
        let mut router = PlacementRouter::new(
            cfg.moe.clone(),
            cfg.cluster.clone(),
            cfg.comm,
            cfg.seed,
        )?;
        router.dedup = cfg.dedup;
        router.wire = cfg.wire;
        router.set_dead(&dead);
        // Operator-pinned replicas install before the first batch; the
        // router rejects dead/primary/out-of-range targets.
        for &(expert, rank) in &cfg.replicas {
            router.add_replica(expert, rank)?;
        }
        let mut rng = Rng::seed(cfg.seed ^ 0xE4B);
        let mut embedding = Tensor::randn(&[cfg.vocab, cfg.moe.d_model], &mut rng);
        embedding.scale(1.0 / (cfg.moe.d_model as f32).sqrt());
        let token_dist = Zipf::new(cfg.vocab, 1.1);
        // Size the admission budget before building the batcher so the
        // constructor's invariants (chunk/budget clamps) stay in force.
        let sched = SchedulerConfig {
            max_batch_tokens: max_tokens_under_budgets(&cfg, &router),
            chunk_tokens: cfg.chunk_tokens,
            max_queue: cfg.max_queue,
        };
        Ok(ServeEngine {
            cfg,
            router,
            batcher: ContinuousBatcher::new(sched),
            embedding,
            token_dist,
            rng,
            clock: 0.0,
            step: 0,
            dead,
            replications: 0,
        })
    }

    /// Analytic service time of one iteration over `tokens` tokens under
    /// uniform routing — used for admission sizing only; real iterations
    /// are charged from their actual (skewed) dispatch plan.
    pub fn service_estimate(&self, tokens: usize) -> f64 {
        service_estimate_for(&self.cfg, &self.router, tokens)
    }

    /// Roofline times of the per-rank compute phases — `(gate, layout,
    /// expert, reverse_layout)` — for a shard of `shard_tokens` tokens
    /// whose busiest rank hosts `rank_rows` expert rows.
    fn phase_times(&self, shard_tokens: usize, rank_rows: usize) -> (f64, f64, f64, f64) {
        phase_times_for(
            &self.cfg,
            self.router.gate.k(),
            shard_tokens,
            rank_rows,
            self.router.placement().max_hosted(),
        )
    }

    /// Simulated service time + phase report for a routed batch. The
    /// expert phase is charged on the *straggler* rank (most received
    /// rows), so routing skew lengthens service like it would on real
    /// hardware. Service time is the **pipeline's critical path**:
    /// the exchange legs are chunked along the destination-rank axis
    /// (same [`crate::pipeline::StagePlan`] decision as the training
    /// pipeline, same traffic matrix) so dispatch-of-chunk-*i* hides under
    /// expert-FFN-of-chunk-*i − 1*; with one chunk this reduces exactly
    /// to the old sum of phases.
    fn step_time(
        &self,
        decision: &RouteDecision,
        batch_tokens: usize,
        faults: Option<&StepFaults>,
    ) -> (f64, StepReport) {
        let w = self.cfg.cluster.world();
        let per = batch_tokens.div_ceil(w);
        let (gate, layout, expert, reverse) =
            self.phase_times(per, decision.max_rank_rows());
        // The straggler-charged expert time, distributed across
        // destination ranks in proportion to the rows each actually
        // received — a hot expert's rank concentrates compute in its
        // chunk and delays that chunk's combine leg, exactly the skew
        // the straggler model exists to capture (totals sum back to
        // `expert`; uniform fallback when the batch kept nothing). The
        // flat-vs-hier half of the StagePlan decision already happened
        // in the router (same shared `pick_schedule`, same counts), so
        // only the chunk half runs here.
        let rows_per_rank: Vec<f64> = (0..w)
            .map(|dst| (0..w).map(|src| decision.counts[src][dst]).sum::<usize>() as f64)
            .collect();
        let total_rows: f64 = rows_per_rank.iter().sum();
        let compute_per_rank: Vec<f64> = if total_rows > 0.0 {
            rows_per_rank.iter().map(|&r| expert * r / total_rows).collect()
        } else {
            vec![expert / w as f64; w]
        };
        let schedule = match decision.comm {
            CommImpl::Flat => Schedule::Flat,
            CommImpl::Hierarchical => Schedule::Hierarchical,
        };
        // Placement-aware wire split for both legs (the forward combine
        // is never deduplicated — it returns distinct per-slot expert
        // outputs — so only the dispatch leg carries the dedup figure).
        // A batch that spread a replicated expert voids dedup's
        // one-host-per-expert premise: its (empty) summary must not
        // override the real NIC bytes, so dedup charging follows the
        // router's `replicated` flag, not just the config switch.
        let dedup_live = self.cfg.dedup && !decision.replicated;
        let row_bytes = self.cfg.moe.d_model * self.cfg.wire.elem_bytes();
        let g = self.cfg.cluster.gpus_per_node;
        let counts_t = transpose_counts(&decision.counts);
        let (wire_fwd, wire_cmb, rows_deduped) = match schedule {
            Schedule::Flat => (
                split_wire_bytes(&decision.counts, row_bytes, g),
                split_wire_bytes(&counts_t, row_bytes, g),
                0usize,
            ),
            Schedule::Hierarchical => {
                let inter =
                    dedup_live.then(|| decision.dedup.dispatch_inter_total(row_bytes));
                (
                    hier_leg_wire_bytes(&decision.counts, row_bytes, g, inter),
                    hier_leg_wire_bytes(&counts_t, row_bytes, g, None),
                    if dedup_live {
                        decision.dedup.dispatch_rows_saved(row_bytes)
                    } else {
                        0
                    },
                )
            }
        };
        let dedup = if dedup_live { Some(&decision.dedup) } else { None };
        let (stage_plan, overlap) = StagePlan::for_schedule(
            &self.router.net,
            &decision.counts,
            row_bytes,
            schedule,
            self.cfg.chunks,
            &compute_per_rank,
            dedup,
            false,
        );
        let mut total = gate + layout + overlap.critical_path + reverse;
        let mut report = StepReport {
            wall: vec![
                ("gate".into(), gate),
                ("layout".into(), layout),
                ("expert".into(), expert),
                ("reverse_layout".into(), reverse),
            ],
            comm: vec![
                ("alltoall_dispatch".into(), overlap.dispatch_total()),
                ("alltoall_combine".into(), overlap.combine_total()),
            ],
            drop_rate: decision.drop_rate,
            padding_waste: decision.padding_waste,
            expert_counts: decision.expert_counts.clone(),
            aux_loss: decision.aux_loss,
            // Serving ships only kept rows (the router's exact counts)
            // and runs experts over exactly the kept tokens. Bytes are
            // split placement-aware through the same helpers the
            // training data path reports from.
            bytes_on_wire: wire_fwd.inter + wire_cmb.inter,
            bytes_intra_node: wire_fwd.intra + wire_cmb.intra,
            rows_deduped,
            expert_flops: 4.0
                * decision.expert_counts.iter().sum::<usize>() as f64
                * (self.cfg.moe.d_model * self.cfg.moe.ffn_hidden) as f64,
            comm_schedule: stage_plan.schedule.name().into(),
            wire: self.cfg.wire.name().into(),
            // Serving is forward-only: no backward legs.
            ..Default::default()
        };
        report.apply_overlap(&overlap);
        // Injected faults stretch the service interval additively:
        // stragglers over the skew-weighted compute profile, NIC
        // degradation over both exchange legs, retry backoff on top.
        // Routing and token data are untouched.
        if let Some(sf) = faults {
            total += crate::fault::apply_to_report(
                &mut report,
                sf,
                &self.router.net,
                &compute_per_rank,
            );
        }
        // Serving charges time analytically, so the whole batch lands on
        // the modeled timeline: compute phases as plain events, the
        // exchange region through the shared overlap renderer.
        if trace::enabled() {
            let at = trace::model_window(total);
            trace::model_event(
                trace::ModelLane::Expert,
                "gate",
                at,
                gate,
                vec![("batch_tokens".into(), batch_tokens.into())],
            );
            trace::model_event(trace::ModelLane::Expert, "layout", at + gate, layout, vec![]);
            trace::model_overlap(
                at + gate + layout,
                "",
                &overlap,
                vec![
                    ("schedule".into(), stage_plan.schedule.name().into()),
                    ("bytes_on_wire".into(), report.bytes_on_wire.into()),
                    ("bytes_intra_node".into(), report.bytes_intra_node.into()),
                    ("rows_deduped".into(), rows_deduped.into()),
                ],
            );
            trace::model_event(
                trace::ModelLane::Expert,
                "reverse_layout",
                at + gate + layout + overlap.critical_path,
                reverse,
                vec![],
            );
        }
        (total, report)
    }

    /// Synthesize embedded token content for a batch (Zipf-distributed
    /// token ids through the shared embedding, like the training
    /// coordinator's lookup).
    fn sample_batch(&mut self, tokens: usize) -> Tensor {
        let d = self.cfg.moe.d_model;
        let mut x = Tensor::zeros(&[tokens, d]);
        for i in 0..tokens {
            let tok = self.token_dist.sample(&mut self.rng) % self.embedding.rows();
            x.row_mut(i).copy_from_slice(self.embedding.row(tok));
        }
        x
    }

    /// Current per-iteration token budget (after admission sizing).
    pub fn batch_token_budget(&self) -> usize {
        self.batcher.cfg.max_batch_tokens
    }

    /// Run the configured workload to completion; returns the report.
    pub fn run(&mut self) -> Result<SloReport> {
        let mut gen = WorkloadGen::new(
            self.cfg.process.clone(),
            self.cfg.min_tokens,
            self.cfg.max_tokens,
            self.cfg.slo,
            self.cfg.seed,
        );
        let arrivals = gen.generate(self.cfg.duration);
        self.run_requests(&arrivals)
    }

    /// One adaptive-placement decision: if the hottest expert's EWMA
    /// load exceeds `replicate_factor` × the mean and it has no copy
    /// yet (serving caps at one extra copy per expert — enough to halve
    /// its fan-in), replicate it onto the least-loaded alive rank
    /// (deterministic: ties break toward the lowest rank id).
    fn maybe_replicate(&mut self) {
        let load = self.router.load().to_vec();
        let hot = self.router.hot_experts(self.cfg.replicate_factor);
        let Some(&expert) = hot.iter().max_by(|a, b| load[**a].total_cmp(&load[**b]))
        else {
            return;
        };
        if self.router.replicas().num_replicas(expert) >= 1 {
            return;
        }
        let placement = self.router.placement();
        let copies = self.router.replicas().copies(expert, &placement);
        let w = self.cfg.cluster.world();
        // Rank load = EWMA load of the experts it hosts (coarse: replica
        // splits are not modeled here; good enough to pick a cold rank).
        let mut rank_load = vec![0.0f64; w];
        for e in 0..self.cfg.moe.num_experts {
            rank_load[placement.rank_of(e)] += load[e];
        }
        let target = (0..w)
            .filter(|r| !self.dead.contains(r) && !copies.contains(r))
            .min_by(|a, b| rank_load[*a].total_cmp(&rank_load[*b]).then(a.cmp(b)));
        if let Some(rank) = target {
            if self.router.add_replica(expert, rank).is_ok() {
                self.replications += 1;
                if trace::enabled() {
                    let mut span = trace::span("replicate");
                    span.arg("expert", expert);
                    span.arg("rank", rank);
                }
            }
        }
    }

    /// Run an explicit arrival sequence (trace replay path).
    pub fn run_requests(&mut self, arrivals: &[Request]) -> Result<SloReport> {
        let mut tracker = SloTracker::new();
        let mut next = 0usize;
        let mut iterations = 0usize;
        // Hard backstop far above any sane run; the clock always
        // advances by a positive service time, so this only trips on a
        // misconfigured cost model.
        let max_iterations = 4_000_000usize;
        loop {
            iterations += 1;
            if iterations > max_iterations {
                return Err(crate::config_err!(
                    "serving loop exceeded {max_iterations} iterations"
                ));
            }
            // Shed dead queued work *before* admitting, so arrivals are
            // never rejected against a queue full of expired requests.
            let expired = self.batcher.expire(self.clock);
            tracker.drop_expired(expired.len());
            while next < arrivals.len() && arrivals[next].arrival <= self.clock {
                if !self.batcher.enqueue(arrivals[next].clone()) {
                    tracker.reject(1);
                }
                next += 1;
            }
            // And again after: when one service interval exceeds the
            // SLO, arrivals can be dead on admission — those sheds must
            // be accounted too (next_batch never drops work itself).
            let expired = self.batcher.expire(self.clock);
            tracker.drop_expired(expired.len());
            tracker.sample_queue_depth(self.batcher.queue_depth());
            match self.batcher.next_batch() {
                Some(plan) => {
                    let stepi = self.step as usize;
                    // Rank kills fire before the batch routes: the
                    // victim's experts remap onto survivors and the
                    // batch shards over the alive ranks only. Serving
                    // has no checkpoint to restore — it routes around.
                    let w = self.cfg.cluster.world();
                    let kills: Vec<usize> = self
                        .cfg
                        .faults
                        .kills_at(stepi)
                        .into_iter()
                        .filter(|r| *r < w && !self.dead.contains(r))
                        .collect();
                    if !kills.is_empty() {
                        self.dead.extend(kills.iter());
                        self.dead.sort_unstable();
                        self.dead.dedup();
                        if self.dead.len() >= w {
                            return Err(crate::fault_err!(
                                "every rank is dead at batch {stepi} — \
                                 nothing left to serve on"
                            ));
                        }
                        self.router.set_dead(&self.dead);
                        tracker.record_rank_failures(kills.len());
                    }
                    let x = self.sample_batch(plan.tokens);
                    let decision = self.router.route_batch(&x, self.step);
                    self.step += 1;
                    let sf = (!self.cfg.faults.is_empty()).then(|| {
                        self.cfg.faults.at_step(stepi, w, self.cfg.cluster.nodes)
                    });
                    let (service, report) =
                        self.step_time(&decision, plan.tokens, sf.as_ref());
                    self.clock += service;
                    tracker.push_step(&report);
                    for req in self.batcher.complete(&plan) {
                        tracker.complete(&req, self.clock);
                    }
                    // Adaptive placement: periodically give the hottest
                    // expert a second copy on the least-loaded rank, so
                    // subsequent batches spread its fan-in.
                    if self.cfg.placement.is_adaptive()
                        && self.cfg.placement_every > 0
                        && stepi > 0
                        && stepi % self.cfg.placement_every == 0
                    {
                        self.maybe_replicate();
                    }
                }
                None => {
                    if next >= arrivals.len() {
                        break; // drained: no queued, active, or future work
                    }
                    // Idle: jump to the next arrival.
                    self.clock = self.clock.max(arrivals[next].arrival);
                }
            }
        }
        let span = self.clock.max(self.cfg.duration);
        Ok(tracker.report(span))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GateKind;

    fn small_cfg() -> ServeConfig {
        ServeConfig {
            cluster: ClusterConfig {
                nodes: 2,
                gpus_per_node: 2,
                ..ClusterConfig::commodity(2)
            },
            moe: MoeConfig {
                num_experts: 8,
                d_model: 16,
                ffn_hidden: 32,
                capacity_factor: 1.5,
                gate: GateKind::Switch,
            },
            process: ArrivalProcess::Poisson { rate: 500.0 },
            duration: 0.5,
            ..ServeConfig::default_run()
        }
    }

    #[test]
    fn engine_completes_offered_requests() {
        let cfg = small_cfg();
        // Ground-truth arrival count from an identical generator: the
        // report must conserve every one of these requests.
        let ground_truth = WorkloadGen::new(
            cfg.process.clone(),
            cfg.min_tokens,
            cfg.max_tokens,
            cfg.slo,
            cfg.seed,
        )
        .generate(cfg.duration)
        .len();
        let mut engine = ServeEngine::new(cfg).unwrap();
        let report = engine.run().unwrap();
        assert!(report.offered > 100, "0.5 s at 500 req/s: {}", report.offered);
        assert_eq!(
            report.completed + report.dropped + report.rejected,
            ground_truth,
            "every generated request must be accounted for"
        );
        assert!(report.completed > 0);
        assert!(report.batches > 0);
        assert!(report.latency.p50 > 0.0);
        assert!(report.latency.p50 <= report.latency.p99);
        assert!(report.goodput_rps > 0.0);
        // Phase breakdown carries the training pipeline's phase names.
        let names: Vec<&str> =
            report.breakdown.phases.iter().map(|(n, _)| n.as_str()).collect();
        for expect in ["gate", "expert", "alltoall_dispatch", "alltoall_combine"] {
            assert!(names.contains(&expect), "missing {expect}: {names:?}");
        }
    }

    #[test]
    fn runs_are_deterministic_per_seed() {
        let run = |seed: u64| {
            let mut cfg = small_cfg();
            cfg.seed = seed;
            let mut engine = ServeEngine::new(cfg).unwrap();
            let r = engine.run().unwrap();
            (r.offered, r.completed, r.latency.p50, r.goodput_tps)
        };
        assert_eq!(run(3), run(3));
        assert_ne!(run(3), run(4));
    }

    #[test]
    fn overload_sheds_instead_of_diverging() {
        let mut cfg = small_cfg();
        // Far beyond what the simulated cluster can serve (its token
        // throughput tops out around a few million tokens/s here).
        cfg.process = ArrivalProcess::Poisson { rate: 1_000_000.0 };
        cfg.duration = 0.1;
        cfg.max_queue = 256;
        let mut engine = ServeEngine::new(cfg).unwrap();
        let report = engine.run().unwrap();
        assert!(report.drop_rate > 0.3, "drop rate {} under overload", report.drop_rate);
        assert!(report.max_queue_depth <= 256.0);
    }

    #[test]
    fn admission_budget_respects_slo_headroom() {
        let engine = ServeEngine::new(small_cfg()).unwrap();
        let budget = engine.batch_token_budget();
        assert!(budget >= 16);
        assert!(budget <= 8 * 256, "expert-capacity budget exceeded: {budget}");
        // One full iteration at the budget fits inside half the SLO.
        if budget > 16 {
            assert!(engine.service_estimate(budget) <= engine.cfg.slo * 0.5);
        }
    }

    #[test]
    fn replica_holder_kill_keeps_goodput_without_recovery() {
        // Expert 0 gets a pinned copy on rank 3; rank 3 dies mid-run.
        // The copy is pruned on the spot — routing continues on the
        // primary, requests keep completing, goodput never hits zero.
        let mut cfg = small_cfg();
        cfg.replicas = vec![(0, 3)];
        cfg.faults = FaultPlan::parse("kill:rank=3,step=5").unwrap();
        let mut engine = ServeEngine::new(cfg).unwrap();
        assert_eq!(engine.router.replicas().num_replicas(0), 1);
        let report = engine.run().unwrap();
        assert_eq!(engine.router.replicas().num_replicas(0), 0);
        assert_eq!(engine.router.dead(), &[3]);
        assert!(report.completed > 0, "requests must keep completing");
        assert!(report.goodput_rps > 0.0, "goodput must survive the kill");
        assert!(report.batches > 5, "the run continues past the kill batch");
    }

    #[test]
    fn adaptive_serving_replicates_a_hot_expert() {
        let mut cfg = small_cfg();
        cfg.placement = PlacementPolicy::Adaptive;
        cfg.placement_every = 2;
        // Zero threshold: any observed load qualifies, so the check
        // definitely fires — what we're testing is the wiring, the
        // deterministic target pick, and that serving stays healthy.
        cfg.replicate_factor = 0.0;
        let mut engine = ServeEngine::new(cfg).unwrap();
        let report = engine.run().unwrap();
        assert!(engine.replications >= 1, "adaptive policy must replicate");
        assert!(!engine.router.replicas().is_empty());
        assert!(report.completed > 0);
        assert!(report.goodput_rps > 0.0);
        // Static runs never replicate.
        let mut st = ServeEngine::new(small_cfg()).unwrap();
        st.run().unwrap();
        assert_eq!(st.replications, 0);
        assert!(st.router.replicas().is_empty());
    }

    #[test]
    fn replicated_batches_are_charged_without_dedup() {
        let mut cfg = small_cfg();
        cfg.replicas = vec![(0, 3)];
        assert!(cfg.dedup);
        let mut engine = ServeEngine::new(cfg).unwrap();
        let x = engine.sample_batch(64);
        let decision = engine.router.route_batch(&x, 0);
        if decision.replicated {
            let (_, report) = engine.step_time(&decision, 64, None);
            assert_eq!(
                report.rows_deduped, 0,
                "dedup must not be charged on a replica-spread batch"
            );
        } else {
            // Expert 0 saw no tokens in this batch — nothing to assert
            // beyond the flag being off.
            assert_eq!(decision.expert_counts[0], 0);
        }
    }

    #[test]
    fn trace_replay_reproduces_a_generated_run() {
        use crate::serve::workload::Trace;
        let cfg = small_cfg();
        let mut gen = WorkloadGen::new(
            cfg.process.clone(),
            cfg.min_tokens,
            cfg.max_tokens,
            cfg.slo,
            cfg.seed,
        );
        let arrivals = gen.generate(cfg.duration);
        let slo = cfg.slo;
        let trace = Trace::from_requests(&arrivals);
        let mut live = ServeEngine::new(cfg.clone()).unwrap();
        let a = live.run().unwrap();
        let mut replay = ServeEngine::new(cfg).unwrap();
        let b = replay.run_requests(&trace.requests(slo)).unwrap();
        assert_eq!(a.offered, b.offered);
        assert_eq!(a.completed, b.completed);
        assert!((a.latency.p50 - b.latency.p50).abs() < 1e-9);
    }
}
