//! Online MoE inference serving (the north star's serving half).
//!
//! The training stack reproduces HetuMoE's fixed-batch iteration; this
//! subsystem turns the same MoE layer into a request-level service on
//! the simulated cluster:
//!
//! - [`workload`] — open-loop Poisson / bursty arrival generation and
//!   replayable [`Trace`]s;
//! - [`scheduler`] — continuous batching: requests join and leave the
//!   running token batch mid-flight, under an expert-capacity token
//!   budget and per-request deadlines;
//! - [`router`] — the training gating zoo plus *placement awareness*:
//!   each batch's dispatch plan is scored against the network model
//!   under flat and hierarchical AllToAll and the cheaper schedule is
//!   chosen per batch, while per-expert EWMA load tracks hot/cold
//!   experts;
//! - [`slo`] — p50/p95/p99 latency, goodput, shed rates and queue depth,
//!   folded into the coordinator's phase-breakdown metrics;
//! - [`engine`] — the deterministic event loop tying it together on the
//!   simulated clock.
//!
//! The serving router is contractually identical to the training path:
//! same gate, same router weight, same capacity rule — asserted against
//! [`crate::moe::MoeLayer`] in `tests/serve_integration.rs`. See
//! DESIGN.md §7.

pub mod engine;
pub mod router;
pub mod scheduler;
pub mod slo;
pub mod workload;

pub use engine::{ServeConfig, ServeEngine};
pub use router::{CommChoice, PlacementRouter, RouteDecision};
pub use scheduler::{BatchPlan, ContinuousBatcher, SchedulerConfig};
pub use slo::{SloReport, SloTracker};
pub use workload::{ArrivalProcess, Request, Trace, WorkloadGen};
