//! Expert-placement-aware routing for the serving path.
//!
//! The router reuses the training stack verbatim — the same
//! [`Gate`] zoo, the same router weight, the same capacity rule — so a
//! token batch routes to *exactly* the experts the training-path
//! [`MoeLayer`] would pick (asserted in `tests/serve_integration.rs`).
//! What serving adds on top is *placement awareness*: knowing that
//! expert `e` lives on rank `e / (E/W)`, the router turns a dispatch
//! plan into a per-(src, dst) rank traffic matrix, scores that matrix
//! against the [`NetworkModel`] under both the flat and the hierarchical
//! AllToAll schedules, and picks the cheaper one **per batch**. Online
//! batches are small and ragged, so the winner genuinely flips with
//! load — at low rate few pairs are populated and flat's direct sends
//! win; near saturation the NIC drowns in small messages and the
//! paper's aggregation wins. It also tracks a per-expert EWMA load so
//! operators can see hot/cold experts drift with the workload.

use crate::cluster::NetworkModel;
use crate::comm::hier_ragged::{dedup_traffic, DedupTraffic};
use crate::comm::schedule::pick_schedule_dedup;
use crate::config::{ClusterConfig, MoeConfig};
use crate::error::Result;
use crate::gating::{apply_capacity, make_gate, DispatchPlan, Gate, Routing};
use crate::moe::{CommImpl, MoeLayer};
use crate::nn::matmul;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

// The AllToAll selection policy lives in `comm::schedule` — the single
// decision procedure shared with the training layer's ragged pipeline —
// and is re-exported here for the serving API surface.
pub use crate::comm::schedule::CommChoice;

/// Routing outcome for one admitted batch.
#[derive(Clone, Debug)]
pub struct RouteDecision {
    /// Per-shard routing + capacity plan, rank order (training layout).
    pub shards: Vec<(Routing, DispatchPlan)>,
    /// `counts[src][dst]`: kept token rows rank `src` ships to `dst`.
    pub counts: Vec<Vec<usize>>,
    /// Node-level dedup summary of the same plans (replica rows, unique
    /// payloads, pre-summable runs per node pair) — the dedup-aware
    /// counts the schedule pick scored, identical to what the training
    /// executor derives from the same plans.
    pub dedup: DedupTraffic,
    /// Global per-expert kept token counts.
    pub expert_counts: Vec<usize>,
    /// Chosen schedule.
    pub comm: CommImpl,
    /// Predicted *unchunked* dispatch-leg time of the chosen schedule
    /// (diagnostic: the engine charges service time through the chunked
    /// overlap model in `pipeline/`, not from this field).
    pub dispatch_time: f64,
    /// Predicted *unchunked* combine-leg time of the chosen schedule —
    /// charged on the **transposed** traffic matrix, since the return
    /// exchange reverses every flow (a hot expert's rank serializes the
    /// sends). Diagnostic, like `dispatch_time`.
    pub combine_time: f64,
    /// Round-trip (dispatch + combine) predicted times per schedule.
    pub flat_time: f64,
    pub hier_time: f64,
    /// Capacity-drop rate across the batch's demanded slots.
    pub drop_rate: f64,
    /// Mean padding waste of the per-shard dispatch buffers.
    pub padding_waste: f64,
    /// Mean auxiliary loss across shards.
    pub aux_loss: f64,
}

impl RouteDecision {
    /// Rows landing on the most-loaded rank (the expert-compute
    /// straggler after the exchange).
    pub fn max_rank_rows(&self) -> usize {
        let w = self.counts.len();
        (0..w)
            .map(|dst| (0..w).map(|src| self.counts[src][dst]).sum::<usize>())
            .max()
            .unwrap_or(0)
    }
}

/// The placement-aware router (see module docs).
pub struct PlacementRouter {
    pub cfg: MoeConfig,
    pub cluster: ClusterConfig,
    pub net: NetworkModel,
    pub gate: Box<dyn Gate>,
    /// Router weight `[d, E]` — identical to the training layer's.
    pub gate_weight: Tensor,
    choice: CommChoice,
    /// Score the hierarchical schedule with top-k dedup (must match the
    /// training side's `MoeLayerOptions::dedup` for the shared per-step
    /// decision to stay identical; both default to on).
    pub dedup: bool,
    /// EWMA of per-expert kept-token load.
    load_ewma: Vec<f64>,
    ewma_alpha: f64,
    flat_chosen: usize,
    hier_chosen: usize,
    /// Ranks currently marked failed: they receive no shard and host no
    /// experts (the placement remaps their experts onto survivors).
    dead: Vec<usize>,
}

impl PlacementRouter {
    /// Build with a freshly initialized router weight (same init recipe
    /// as [`MoeLayer::native`]).
    pub fn new(
        cfg: MoeConfig,
        cluster: ClusterConfig,
        choice: CommChoice,
        seed: u64,
    ) -> Result<PlacementRouter> {
        cfg.validate()?;
        let mut rng = Rng::seed(seed ^ 0x10_07E5);
        let mut gate_weight = Tensor::randn(&[cfg.d_model, cfg.num_experts], &mut rng);
        gate_weight.scale(1.0 / (cfg.d_model as f32).sqrt());
        Self::with_weight(cfg, cluster, choice, gate_weight)
    }

    /// Build sharing an existing training layer's gate config and router
    /// weight — the serving path then routes exactly as training does,
    /// including scoring (or not scoring) dedup-aware NIC bytes: the
    /// layer's `dedup` option is mirrored so the shared per-step
    /// schedule decision sees identical inputs on both sides.
    pub fn from_layer(layer: &MoeLayer, choice: CommChoice) -> Result<PlacementRouter> {
        let mut router = Self::with_weight(
            layer.cfg.clone(),
            layer.cluster.clone(),
            choice,
            layer.gate_weight.clone(),
        )?;
        router.dedup = layer.opts.dedup;
        Ok(router)
    }

    fn with_weight(
        cfg: MoeConfig,
        cluster: ClusterConfig,
        choice: CommChoice,
        gate_weight: Tensor,
    ) -> Result<PlacementRouter> {
        let w = cluster.world();
        if cfg.num_experts % w != 0 {
            return Err(crate::config_err!(
                "num_experts {} must divide by world {w}",
                cfg.num_experts
            ));
        }
        let gate = make_gate(&cfg, 1, None)?;
        let net = NetworkModel::new(cluster.clone());
        let e = cfg.num_experts;
        Ok(PlacementRouter {
            cfg,
            cluster,
            net,
            gate,
            gate_weight,
            choice,
            dedup: true,
            load_ewma: vec![0.0; e],
            ewma_alpha: 0.2,
            flat_chosen: 0,
            hier_chosen: 0,
            dead: Vec::new(),
        })
    }

    /// Mark `dead` ranks failed: subsequent batches shard only over the
    /// survivors and the placement remaps the dead ranks' experts.
    pub fn set_dead(&mut self, dead: &[usize]) {
        self.dead = dead.to_vec();
        self.dead.sort_unstable();
        self.dead.dedup();
    }

    /// Ranks currently routed around.
    pub fn dead(&self) -> &[usize] {
        &self.dead
    }

    /// The shared expert-placement map (identical to the training
    /// layer's — see [`crate::cluster::ExpertPlacement`]); with dead
    /// ranks it is the elastic remap over the survivors.
    pub fn placement(&self) -> crate::cluster::ExpertPlacement {
        crate::cluster::ExpertPlacement::with_dead(
            self.cfg.num_experts,
            self.cluster.world(),
            &self.dead,
        )
    }

    /// Experts hosted per rank.
    pub fn experts_per_rank(&self) -> usize {
        self.placement().experts_per_rank()
    }

    /// Rank hosting a global expert id (the training-path placement).
    pub fn rank_of_expert(&self, expert: usize) -> usize {
        self.placement().rank_of(expert)
    }

    /// Route one per-rank shard exactly like the training pipeline:
    /// score matmul → gate → capacity plan.
    pub fn route_shard(&self, shard: &Tensor, step: u64) -> (Routing, DispatchPlan) {
        let scores = matmul(shard, &self.gate_weight);
        let routing = self.gate.route_scores(&scores, step);
        let cap = self.cfg.capacity(shard.rows());
        let plan = apply_capacity(&routing, cap);
        (routing, plan)
    }

    /// Route a whole admitted batch `[T, d]`: shard it contiguously
    /// across the world (training layout), route every shard, build the
    /// rank traffic matrix, and pick the AllToAll schedule.
    pub fn route_batch(&mut self, batch: &Tensor, step: u64) -> RouteDecision {
        let w = self.cluster.world();
        let tokens = batch.rows();
        // Dead ranks take no tokens: the batch shards over the alive
        // ranks only (identical to sharding over everyone when the dead
        // set is empty).
        let n_alive = (w - self.dead.len()).max(1);
        let per = tokens.div_ceil(n_alive);
        let mut shards = Vec::with_capacity(w);
        let mut alive_idx = 0usize;
        for r in 0..w {
            let (lo, hi) = if self.dead.binary_search(&r).is_ok() {
                (0, 0)
            } else {
                let i = alive_idx;
                alive_idx += 1;
                ((i * per).min(tokens), ((i + 1) * per).min(tokens))
            };
            let shard = batch.slice_rows(lo, hi);
            if shard.rows() == 0 {
                let routing = Routing {
                    k: self.gate.k(),
                    tokens: 0,
                    num_experts: self.cfg.num_experts,
                    expert_ids: Vec::new(),
                    weights: Vec::new(),
                    aux_loss: 0.0,
                };
                let plan = apply_capacity(&routing, 1);
                shards.push((routing, plan));
            } else {
                shards.push(self.route_shard(&shard, step));
            }
        }

        // Traffic matrix + per-expert loads from the kept slots.
        let mut counts = vec![vec![0usize; w]; w];
        let mut expert_counts = vec![0usize; self.cfg.num_experts];
        let mut demanded = 0usize;
        let mut dropped = 0usize;
        let mut waste = 0.0f64;
        let mut aux = 0.0f64;
        let mut occupied = 0usize;
        for (src, (routing, plan)) in shards.iter().enumerate() {
            for (slot, &dest) in plan.dest.iter().enumerate() {
                if dest == u32::MAX {
                    continue;
                }
                let expert = routing.expert_ids[slot] as usize;
                counts[src][self.rank_of_expert(expert)] += 1;
                expert_counts[expert] += 1;
            }
            demanded += plan.demand.iter().sum::<usize>();
            dropped += plan.dropped_slots();
            // Empty shards (small batches on big worlds) carry no
            // dispatch buffer; averaging their vacuous 100%-waste plans
            // in would swamp the metric.
            if routing.tokens > 0 {
                waste += plan.padding_waste();
                aux += routing.aux_loss as f64;
                occupied += 1;
            }
        }
        let occupied_f = occupied.max(1) as f64;
        let waste = waste / occupied_f;
        let aux = aux / occupied_f;

        // Score both schedules over the full round trip via the shared
        // decision procedure (`comm::schedule`): the combine leg is the
        // transpose of the dispatch matrix (every flow reverses), and
        // under expert skew the two legs cost very different amounts —
        // a hot expert's rank receives fan-in cheaply but serializes
        // the whole fan-out on the way back. The hierarchical side is
        // scored on the dedup-aware node-level counts — the identical
        // summary the training executor derives from the same plans.
        let placement = self.placement();
        let dedup = if self.dedup {
            dedup_traffic(shards.iter().map(|(_, p)| p), &placement, &self.cluster)
        } else {
            // Dedup off: skip the per-slot scan — the summary is never
            // scored and the engine ignores it.
            DedupTraffic::empty(&self.cluster)
        };
        let row_bytes = self.cfg.d_model * 4;
        let pick = pick_schedule_dedup(
            &self.net,
            &counts,
            row_bytes,
            self.choice,
            self.dedup.then_some(&dedup),
        );
        let comm = CommImpl::from(pick.schedule);
        match comm {
            CommImpl::Flat => self.flat_chosen += 1,
            CommImpl::Hierarchical => self.hier_chosen += 1,
        }
        self.observe(&expert_counts);

        RouteDecision {
            shards,
            counts,
            dedup,
            expert_counts,
            comm,
            dispatch_time: pick.dispatch_time,
            combine_time: pick.combine_time,
            flat_time: pick.flat_time,
            hier_time: pick.hier_time,
            drop_rate: dropped as f64 / demanded.max(1) as f64,
            padding_waste: waste,
            aux_loss: aux,
        }
    }

    /// Fold a batch's per-expert loads into the EWMA tracker.
    fn observe(&mut self, expert_counts: &[usize]) {
        let a = self.ewma_alpha;
        for (ewma, &c) in self.load_ewma.iter_mut().zip(expert_counts) {
            *ewma = (1.0 - a) * *ewma + a * c as f64;
        }
    }

    /// Smoothed per-expert load.
    pub fn load(&self) -> &[f64] {
        &self.load_ewma
    }

    /// Experts whose smoothed load exceeds `factor` × the mean load.
    pub fn hot_experts(&self, factor: f64) -> Vec<usize> {
        let mean = self.load_ewma.iter().sum::<f64>() / self.load_ewma.len().max(1) as f64;
        if mean <= 0.0 {
            return Vec::new();
        }
        self.load_ewma
            .iter()
            .enumerate()
            .filter(|(_, &l)| l > factor * mean)
            .map(|(e, _)| e)
            .collect()
    }

    /// Experts whose smoothed load is below `factor` × the mean load —
    /// candidates for consolidation/eviction.
    pub fn cold_experts(&self, factor: f64) -> Vec<usize> {
        let mean = self.load_ewma.iter().sum::<f64>() / self.load_ewma.len().max(1) as f64;
        self.load_ewma
            .iter()
            .enumerate()
            .filter(|(_, &l)| l < factor * mean)
            .map(|(e, _)| e)
            .collect()
    }

    /// `(flat, hierarchical)` batch counts chosen so far.
    pub fn comm_decisions(&self) -> (usize, usize) {
        (self.flat_chosen, self.hier_chosen)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GateKind;

    fn cfg(gate: GateKind) -> MoeConfig {
        MoeConfig {
            num_experts: 8,
            d_model: 16,
            ffn_hidden: 32,
            capacity_factor: 2.0,
            gate,
        }
    }

    fn cluster(nodes: usize, gpus: usize) -> ClusterConfig {
        ClusterConfig { nodes, gpus_per_node: gpus, ..ClusterConfig::commodity(nodes) }
    }

    #[test]
    fn placement_matches_training_layout() {
        let r = PlacementRouter::new(
            cfg(GateKind::Switch),
            cluster(2, 2),
            CommChoice::Auto,
            0,
        )
        .unwrap();
        assert_eq!(r.experts_per_rank(), 2);
        assert_eq!(r.rank_of_expert(0), 0);
        assert_eq!(r.rank_of_expert(3), 1);
        assert_eq!(r.rank_of_expert(7), 3);
    }

    #[test]
    fn traffic_matrix_conserves_kept_tokens() {
        let mut r = PlacementRouter::new(
            cfg(GateKind::Switch),
            cluster(2, 2),
            CommChoice::Auto,
            1,
        )
        .unwrap();
        let mut rng = Rng::seed(5);
        let x = Tensor::randn(&[64, 16], &mut rng);
        let d = r.route_batch(&x, 0);
        let matrix_total: usize = d.counts.iter().flatten().sum();
        let expert_total: usize = d.expert_counts.iter().sum();
        let kept_total: usize =
            d.shards.iter().map(|(_, p)| p.kept.iter().sum::<usize>()).sum();
        assert_eq!(matrix_total, expert_total);
        assert_eq!(matrix_total, kept_total);
        assert!(matrix_total <= 64); // top-1 gate: at most one slot/token
        assert!(d.flat_time >= 0.0 && d.hier_time > 0.0);
        assert!(d.max_rank_rows() >= matrix_total / 4);
    }

    #[test]
    fn auto_choice_picks_the_cheaper_schedule() {
        let mut r = PlacementRouter::new(
            cfg(GateKind::Switch),
            cluster(2, 4),
            CommChoice::Auto,
            2,
        )
        .unwrap();
        let mut rng = Rng::seed(9);
        let x = Tensor::randn(&[128, 16], &mut rng);
        let d = r.route_batch(&x, 0);
        match d.comm {
            CommImpl::Flat => assert!(d.flat_time <= d.hier_time),
            CommImpl::Hierarchical => assert!(d.hier_time < d.flat_time),
        }
        let (f, h) = r.comm_decisions();
        assert_eq!(f + h, 1);
    }

    #[test]
    fn forced_choices_are_respected() {
        for (choice, expect) in [
            (CommChoice::Flat, CommImpl::Flat),
            (CommChoice::Hierarchical, CommImpl::Hierarchical),
        ] {
            let mut r =
                PlacementRouter::new(cfg(GateKind::Switch), cluster(2, 2), choice, 3)
                    .unwrap();
            let mut rng = Rng::seed(11);
            let x = Tensor::randn(&[32, 16], &mut rng);
            assert_eq!(r.route_batch(&x, 0).comm, expect);
        }
    }

    #[test]
    fn ewma_tracks_hot_experts() {
        let mut r = PlacementRouter::new(
            cfg(GateKind::Switch),
            cluster(1, 2),
            CommChoice::Auto,
            4,
        )
        .unwrap();
        // Skewed loads: expert 0 hot, everyone else cold.
        for _ in 0..10 {
            r.observe(&[80, 2, 2, 2, 2, 2, 2, 2]);
        }
        let hot = r.hot_experts(1.5);
        assert_eq!(hot, vec![0]);
        let cold = r.cold_experts(0.5);
        assert_eq!(cold, vec![1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn tiny_batches_shard_without_panicking() {
        let mut r = PlacementRouter::new(
            cfg(GateKind::GShard),
            cluster(2, 2),
            CommChoice::Auto,
            6,
        )
        .unwrap();
        let mut rng = Rng::seed(13);
        // Fewer tokens than ranks → some shards are empty.
        let x = Tensor::randn(&[2, 16], &mut rng);
        let d = r.route_batch(&x, 0);
        assert_eq!(d.shards.len(), 4);
        let kept: usize = d.expert_counts.iter().sum();
        assert!(kept >= 2, "top-2 over 2 tokens keeps >= 2 slots, got {kept}");
        assert!(CommChoice::parse("nonsense").is_err());
        assert_eq!(CommChoice::parse("hier").unwrap(), CommChoice::Hierarchical);
    }
}
