//! Expert-placement-aware routing for the serving path.
//!
//! The router reuses the training stack verbatim — the same
//! [`Gate`] zoo, the same router weight, the same capacity rule — so a
//! token batch routes to *exactly* the experts the training-path
//! [`MoeLayer`] would pick (asserted in `tests/serve_integration.rs`).
//! What serving adds on top is *placement awareness*: knowing that
//! expert `e` lives on rank `e / (E/W)`, the router turns a dispatch
//! plan into a per-(src, dst) rank traffic matrix, scores that matrix
//! against the [`NetworkModel`] under both the flat and the hierarchical
//! AllToAll schedules, and picks the cheaper one **per batch**. Online
//! batches are small and ragged, so the winner genuinely flips with
//! load — at low rate few pairs are populated and flat's direct sends
//! win; near saturation the NIC drowns in small messages and the
//! paper's aggregation wins. It also tracks a per-expert EWMA load so
//! operators can see hot/cold experts drift with the workload.
//!
//! Two placement extensions ride on the same machinery:
//!
//! * an **installed table** ([`PlacementRouter::set_table`]) replaces
//!   the contiguous formula with an arbitrary expert→rank assignment —
//!   the adaptive optimizer's output — and composes with dead-rank
//!   remapping exactly like the training side;
//! * **replicas** ([`PlacementRouter::add_replica`]) give hot experts
//!   extra host ranks. Routed slots for a replicated expert rotate
//!   deterministically over its live copies (a per-expert round-robin
//!   counter — same batch sequence, same spread), so a hot expert's
//!   fan-in splits across NICs. Killing a replica holder just prunes
//!   that copy: surviving copies absorb the load with no recovery
//!   window. Dedup scoring assumes one host per expert, so any batch
//!   that actually spread a replicated expert is scored without dedup
//!   (flagged via [`RouteDecision::replicated`]).

use crate::cluster::NetworkModel;
use crate::comm::hier_ragged::{dedup_traffic, DedupTraffic};
use crate::comm::schedule::pick_schedule_dedup;
use crate::comm::WirePrecision;
use crate::config::{ClusterConfig, MoeConfig};
use crate::error::Result;
use crate::gating::{apply_capacity, make_gate, DispatchPlan, Gate, Routing};
use crate::moe::{CommImpl, MoeLayer};
use crate::nn::matmul;
use crate::placement::ReplicaMap;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

// The AllToAll selection policy lives in `comm::schedule` — the single
// decision procedure shared with the training layer's ragged pipeline —
// and is re-exported here for the serving API surface.
pub use crate::comm::schedule::CommChoice;

/// Routing outcome for one admitted batch.
#[derive(Clone, Debug)]
pub struct RouteDecision {
    /// Per-shard routing + capacity plan, rank order (training layout).
    pub shards: Vec<(Routing, DispatchPlan)>,
    /// `counts[src][dst]`: kept token rows rank `src` ships to `dst`.
    pub counts: Vec<Vec<usize>>,
    /// Node-level dedup summary of the same plans (replica rows, unique
    /// payloads, pre-summable runs per node pair) — the dedup-aware
    /// counts the schedule pick scored, identical to what the training
    /// executor derives from the same plans.
    pub dedup: DedupTraffic,
    /// Global per-expert kept token counts.
    pub expert_counts: Vec<usize>,
    /// Chosen schedule.
    pub comm: CommImpl,
    /// Predicted *unchunked* dispatch-leg time of the chosen schedule
    /// (diagnostic: the engine charges service time through the chunked
    /// overlap model in `pipeline/`, not from this field).
    pub dispatch_time: f64,
    /// Predicted *unchunked* combine-leg time of the chosen schedule —
    /// charged on the **transposed** traffic matrix, since the return
    /// exchange reverses every flow (a hot expert's rank serializes the
    /// sends). Diagnostic, like `dispatch_time`.
    pub combine_time: f64,
    /// Round-trip (dispatch + combine) predicted times per schedule.
    pub flat_time: f64,
    pub hier_time: f64,
    /// Capacity-drop rate across the batch's demanded slots.
    pub drop_rate: f64,
    /// Mean padding waste of the per-shard dispatch buffers.
    pub padding_waste: f64,
    /// Mean auxiliary loss across shards.
    pub aux_loss: f64,
    /// True when at least one routed slot went to a replica copy rather
    /// than the expert's primary rank. Dedup's one-host-per-expert
    /// premise is void for such a batch, so it was scored (and must be
    /// charged) without the dedup override.
    pub replicated: bool,
}

impl RouteDecision {
    /// Rows landing on the most-loaded rank (the expert-compute
    /// straggler after the exchange).
    pub fn max_rank_rows(&self) -> usize {
        let w = self.counts.len();
        (0..w)
            .map(|dst| (0..w).map(|src| self.counts[src][dst]).sum::<usize>())
            .max()
            .unwrap_or(0)
    }
}

/// The placement-aware router (see module docs).
pub struct PlacementRouter {
    pub cfg: MoeConfig,
    pub cluster: ClusterConfig,
    pub net: NetworkModel,
    pub gate: Box<dyn Gate>,
    /// Router weight `[d, E]` — identical to the training layer's.
    pub gate_weight: Tensor,
    choice: CommChoice,
    /// Score the hierarchical schedule with top-k dedup (must match the
    /// training side's `MoeLayerOptions::dedup` for the shared per-step
    /// decision to stay identical; both default to on).
    pub dedup: bool,
    /// Wire element format batches are scored (and charged) at — must
    /// match the executing layer's `MoeLayerOptions::wire` for the
    /// shared schedule decision to see identical byte counts; both
    /// default to f32.
    pub wire: WirePrecision,
    /// EWMA of per-expert kept-token load.
    load_ewma: Vec<f64>,
    ewma_alpha: f64,
    flat_chosen: usize,
    hier_chosen: usize,
    /// Ranks currently marked failed: they receive no shard and host no
    /// experts (the placement remaps their experts onto survivors).
    dead: Vec<usize>,
    /// Installed expert→rank table (adaptive placement); `None` keeps
    /// the contiguous formula.
    table: Option<Vec<usize>>,
    /// Extra host ranks per expert (hot-expert replicas).
    replicas: ReplicaMap,
    /// Per-expert round-robin cursor over an expert's copies — the
    /// deterministic tie-break for replica spread.
    rr: Vec<usize>,
}

impl PlacementRouter {
    /// Build with a freshly initialized router weight (same init recipe
    /// as [`MoeLayer::native`]).
    pub fn new(
        cfg: MoeConfig,
        cluster: ClusterConfig,
        choice: CommChoice,
        seed: u64,
    ) -> Result<PlacementRouter> {
        cfg.validate()?;
        let mut rng = Rng::seed(seed ^ 0x10_07E5);
        let mut gate_weight = Tensor::randn(&[cfg.d_model, cfg.num_experts], &mut rng);
        gate_weight.scale(1.0 / (cfg.d_model as f32).sqrt());
        Self::with_weight(cfg, cluster, choice, gate_weight)
    }

    /// Build sharing an existing training layer's gate config and router
    /// weight — the serving path then routes exactly as training does,
    /// including scoring (or not scoring) dedup-aware NIC bytes: the
    /// layer's `dedup` option is mirrored so the shared per-step
    /// schedule decision sees identical inputs on both sides.
    pub fn from_layer(layer: &MoeLayer, choice: CommChoice) -> Result<PlacementRouter> {
        let mut router = Self::with_weight(
            layer.cfg.clone(),
            layer.cluster.clone(),
            choice,
            layer.gate_weight.clone(),
        )?;
        router.dedup = layer.opts.dedup;
        router.wire = layer.opts.wire;
        Ok(router)
    }

    fn with_weight(
        cfg: MoeConfig,
        cluster: ClusterConfig,
        choice: CommChoice,
        gate_weight: Tensor,
    ) -> Result<PlacementRouter> {
        let w = cluster.world();
        if cfg.num_experts % w != 0 {
            return Err(crate::config_err!(
                "num_experts {} must divide by world {w}",
                cfg.num_experts
            ));
        }
        let gate = make_gate(&cfg, 1, None)?;
        let net = NetworkModel::new(cluster.clone());
        let e = cfg.num_experts;
        Ok(PlacementRouter {
            cfg,
            cluster,
            net,
            gate,
            gate_weight,
            choice,
            dedup: true,
            wire: WirePrecision::F32,
            load_ewma: vec![0.0; e],
            ewma_alpha: 0.2,
            flat_chosen: 0,
            hier_chosen: 0,
            dead: Vec::new(),
            table: None,
            replicas: ReplicaMap::new(e),
            rr: vec![0; e],
        })
    }

    /// Mark `dead` ranks failed: subsequent batches shard only over the
    /// survivors, the placement remaps the dead ranks' experts, and any
    /// replica copy they hosted is dropped — affected experts degrade
    /// to their surviving copies immediately, no recovery window.
    pub fn set_dead(&mut self, dead: &[usize]) {
        self.dead = dead.to_vec();
        self.dead.sort_unstable();
        self.dead.dedup();
        for &r in &self.dead {
            self.replicas.remove_rank(r);
        }
    }

    /// Ranks currently routed around.
    pub fn dead(&self) -> &[usize] {
        &self.dead
    }

    /// The shared expert-placement map (identical to the training
    /// layer's — see [`crate::cluster::ExpertPlacement`]): the
    /// installed table when one is set, else the contiguous formula;
    /// with dead ranks it is the elastic remap over the survivors.
    pub fn placement(&self) -> crate::cluster::ExpertPlacement {
        crate::cluster::ExpertPlacement::resolve(
            self.cfg.num_experts,
            self.cluster.world(),
            self.table.as_deref(),
            &self.dead,
        )
    }

    /// Install an adaptive expert→rank table (`None` restores the
    /// contiguous formula). The table is validated against the config.
    pub fn set_table(&mut self, table: Option<Vec<usize>>) -> Result<()> {
        if let Some(t) = &table {
            crate::cluster::ExpertPlacement::validate_table(
                self.cfg.num_experts,
                self.cluster.world(),
                t,
            )?;
        }
        self.table = table;
        Ok(())
    }

    /// Add a replica of `expert` on `rank`. A replica on the expert's
    /// own primary rank (or a dead rank) is meaningless and rejected.
    pub fn add_replica(&mut self, expert: usize, rank: usize) -> Result<()> {
        if expert >= self.cfg.num_experts {
            return Err(crate::config_err!(
                "replica expert {expert} outside 0..{}",
                self.cfg.num_experts
            ));
        }
        if rank >= self.cluster.world() {
            return Err(crate::config_err!(
                "replica rank {rank} outside world {}",
                self.cluster.world()
            ));
        }
        if self.dead.binary_search(&rank).is_ok() {
            return Err(crate::config_err!("replica rank {rank} is dead"));
        }
        if self.placement().rank_of(expert) == rank {
            return Err(crate::config_err!(
                "expert {expert} already lives on rank {rank}"
            ));
        }
        self.replicas.add(expert, rank);
        Ok(())
    }

    /// The live replica map (primary ranks not included).
    pub fn replicas(&self) -> &ReplicaMap {
        &self.replicas
    }

    /// Experts hosted per rank.
    pub fn experts_per_rank(&self) -> usize {
        self.placement().experts_per_rank()
    }

    /// Rank hosting a global expert id (the training-path placement).
    pub fn rank_of_expert(&self, expert: usize) -> usize {
        self.placement().rank_of(expert)
    }

    /// Route one per-rank shard exactly like the training pipeline:
    /// score matmul → gate → capacity plan.
    pub fn route_shard(&self, shard: &Tensor, step: u64) -> (Routing, DispatchPlan) {
        let scores = matmul(shard, &self.gate_weight);
        let routing = self.gate.route_scores(&scores, step);
        let cap = self.cfg.capacity(shard.rows());
        let plan = apply_capacity(&routing, cap);
        (routing, plan)
    }

    /// Route a whole admitted batch `[T, d]`: shard it contiguously
    /// across the world (training layout), route every shard, build the
    /// rank traffic matrix, and pick the AllToAll schedule.
    pub fn route_batch(&mut self, batch: &Tensor, step: u64) -> RouteDecision {
        let w = self.cluster.world();
        let tokens = batch.rows();
        // Dead ranks take no tokens: the batch shards over the alive
        // ranks only (identical to sharding over everyone when the dead
        // set is empty).
        let n_alive = (w - self.dead.len()).max(1);
        let per = tokens.div_ceil(n_alive);
        let mut shards = Vec::with_capacity(w);
        let mut alive_idx = 0usize;
        for r in 0..w {
            let (lo, hi) = if self.dead.binary_search(&r).is_ok() {
                (0, 0)
            } else {
                let i = alive_idx;
                alive_idx += 1;
                ((i * per).min(tokens), ((i + 1) * per).min(tokens))
            };
            let shard = batch.slice_rows(lo, hi);
            if shard.rows() == 0 {
                let routing = Routing {
                    k: self.gate.k(),
                    tokens: 0,
                    num_experts: self.cfg.num_experts,
                    expert_ids: Vec::new(),
                    weights: Vec::new(),
                    aux_loss: 0.0,
                };
                let plan = apply_capacity(&routing, 1);
                shards.push((routing, plan));
            } else {
                shards.push(self.route_shard(&shard, step));
            }
        }

        // Traffic matrix + per-expert loads from the kept slots. A
        // replicated expert's slots rotate over its live copies
        // (deterministic per-expert round-robin), splitting the hot
        // fan-in across NICs; everyone else goes to the placement's
        // single host.
        let placement = self.placement();
        let mut counts = vec![vec![0usize; w]; w];
        let mut expert_counts = vec![0usize; self.cfg.num_experts];
        let mut demanded = 0usize;
        let mut dropped = 0usize;
        let mut waste = 0.0f64;
        let mut aux = 0.0f64;
        let mut occupied = 0usize;
        let mut replicated = false;
        for (src, (routing, plan)) in shards.iter().enumerate() {
            for (slot, &dest) in plan.dest.iter().enumerate() {
                if dest == u32::MAX {
                    continue;
                }
                let expert = routing.expert_ids[slot] as usize;
                let dst = if self.replicas.num_replicas(expert) > 0 {
                    let targets = self.replicas.copies(expert, &placement);
                    let t = targets[self.rr[expert] % targets.len()];
                    self.rr[expert] += 1;
                    replicated = true;
                    t
                } else {
                    placement.rank_of(expert)
                };
                counts[src][dst] += 1;
                expert_counts[expert] += 1;
            }
            demanded += plan.demand.iter().sum::<usize>();
            dropped += plan.dropped_slots();
            // Empty shards (small batches on big worlds) carry no
            // dispatch buffer; averaging their vacuous 100%-waste plans
            // in would swamp the metric.
            if routing.tokens > 0 {
                waste += plan.padding_waste();
                aux += routing.aux_loss as f64;
                occupied += 1;
            }
        }
        let occupied_f = occupied.max(1) as f64;
        let waste = waste / occupied_f;
        let aux = aux / occupied_f;

        // Score both schedules over the full round trip via the shared
        // decision procedure (`comm::schedule`): the combine leg is the
        // transpose of the dispatch matrix (every flow reverses), and
        // under expert skew the two legs cost very different amounts —
        // a hot expert's rank receives fan-in cheaply but serializes
        // the whole fan-out on the way back. The hierarchical side is
        // scored on the dedup-aware node-level counts — the identical
        // summary the training executor derives from the same plans.
        // A batch that actually spread a replicated expert breaks
        // dedup's one-host-per-expert premise — the node-level summary
        // would describe traffic that never happens — so such batches
        // are scored without the dedup override.
        let dedup_live = self.dedup && !replicated;
        let dedup = if dedup_live {
            dedup_traffic(shards.iter().map(|(_, p)| p), &placement, &self.cluster)
                .with_wire(self.wire)
        } else {
            // Dedup off (or voided by replicas): skip the per-slot scan
            // — the summary is never scored and the engine ignores it.
            DedupTraffic::empty(&self.cluster)
        };
        let row_bytes = self.cfg.d_model * self.wire.elem_bytes();
        let pick = pick_schedule_dedup(
            &self.net,
            &counts,
            row_bytes,
            self.choice,
            dedup_live.then_some(&dedup),
        );
        let comm = CommImpl::from(pick.schedule);
        match comm {
            CommImpl::Flat => self.flat_chosen += 1,
            CommImpl::Hierarchical => self.hier_chosen += 1,
        }
        self.observe(&expert_counts);

        RouteDecision {
            shards,
            counts,
            dedup,
            expert_counts,
            comm,
            dispatch_time: pick.dispatch_time,
            combine_time: pick.combine_time,
            flat_time: pick.flat_time,
            hier_time: pick.hier_time,
            drop_rate: dropped as f64 / demanded.max(1) as f64,
            padding_waste: waste,
            aux_loss: aux,
            replicated,
        }
    }

    /// Fold a batch's per-expert loads into the EWMA tracker.
    fn observe(&mut self, expert_counts: &[usize]) {
        let a = self.ewma_alpha;
        for (ewma, &c) in self.load_ewma.iter_mut().zip(expert_counts) {
            *ewma = (1.0 - a) * *ewma + a * c as f64;
        }
    }

    /// Smoothed per-expert load.
    pub fn load(&self) -> &[f64] {
        &self.load_ewma
    }

    /// Experts whose smoothed load exceeds `factor` × the mean load.
    pub fn hot_experts(&self, factor: f64) -> Vec<usize> {
        let mean = self.load_ewma.iter().sum::<f64>() / self.load_ewma.len().max(1) as f64;
        if mean <= 0.0 {
            return Vec::new();
        }
        self.load_ewma
            .iter()
            .enumerate()
            .filter(|(_, &l)| l > factor * mean)
            .map(|(e, _)| e)
            .collect()
    }

    /// Experts whose smoothed load is below `factor` × the mean load —
    /// candidates for consolidation/eviction.
    pub fn cold_experts(&self, factor: f64) -> Vec<usize> {
        let mean = self.load_ewma.iter().sum::<f64>() / self.load_ewma.len().max(1) as f64;
        self.load_ewma
            .iter()
            .enumerate()
            .filter(|(_, &l)| l < factor * mean)
            .map(|(e, _)| e)
            .collect()
    }

    /// `(flat, hierarchical)` batch counts chosen so far.
    pub fn comm_decisions(&self) -> (usize, usize) {
        (self.flat_chosen, self.hier_chosen)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GateKind;

    fn cfg(gate: GateKind) -> MoeConfig {
        MoeConfig {
            num_experts: 8,
            d_model: 16,
            ffn_hidden: 32,
            capacity_factor: 2.0,
            gate,
        }
    }

    fn cluster(nodes: usize, gpus: usize) -> ClusterConfig {
        ClusterConfig { nodes, gpus_per_node: gpus, ..ClusterConfig::commodity(nodes) }
    }

    #[test]
    fn placement_matches_training_layout() {
        let r = PlacementRouter::new(
            cfg(GateKind::Switch),
            cluster(2, 2),
            CommChoice::Auto,
            0,
        )
        .unwrap();
        assert_eq!(r.experts_per_rank(), 2);
        assert_eq!(r.rank_of_expert(0), 0);
        assert_eq!(r.rank_of_expert(3), 1);
        assert_eq!(r.rank_of_expert(7), 3);
    }

    #[test]
    fn traffic_matrix_conserves_kept_tokens() {
        let mut r = PlacementRouter::new(
            cfg(GateKind::Switch),
            cluster(2, 2),
            CommChoice::Auto,
            1,
        )
        .unwrap();
        let mut rng = Rng::seed(5);
        let x = Tensor::randn(&[64, 16], &mut rng);
        let d = r.route_batch(&x, 0);
        let matrix_total: usize = d.counts.iter().flatten().sum();
        let expert_total: usize = d.expert_counts.iter().sum();
        let kept_total: usize =
            d.shards.iter().map(|(_, p)| p.kept.iter().sum::<usize>()).sum();
        assert_eq!(matrix_total, expert_total);
        assert_eq!(matrix_total, kept_total);
        assert!(matrix_total <= 64); // top-1 gate: at most one slot/token
        assert!(d.flat_time >= 0.0 && d.hier_time > 0.0);
        assert!(d.max_rank_rows() >= matrix_total / 4);
    }

    #[test]
    fn auto_choice_picks_the_cheaper_schedule() {
        let mut r = PlacementRouter::new(
            cfg(GateKind::Switch),
            cluster(2, 4),
            CommChoice::Auto,
            2,
        )
        .unwrap();
        let mut rng = Rng::seed(9);
        let x = Tensor::randn(&[128, 16], &mut rng);
        let d = r.route_batch(&x, 0);
        match d.comm {
            CommImpl::Flat => assert!(d.flat_time <= d.hier_time),
            CommImpl::Hierarchical => assert!(d.hier_time < d.flat_time),
        }
        let (f, h) = r.comm_decisions();
        assert_eq!(f + h, 1);
    }

    #[test]
    fn forced_choices_are_respected() {
        for (choice, expect) in [
            (CommChoice::Flat, CommImpl::Flat),
            (CommChoice::Hierarchical, CommImpl::Hierarchical),
        ] {
            let mut r =
                PlacementRouter::new(cfg(GateKind::Switch), cluster(2, 2), choice, 3)
                    .unwrap();
            let mut rng = Rng::seed(11);
            let x = Tensor::randn(&[32, 16], &mut rng);
            assert_eq!(r.route_batch(&x, 0).comm, expect);
        }
    }

    #[test]
    fn ewma_tracks_hot_experts() {
        let mut r = PlacementRouter::new(
            cfg(GateKind::Switch),
            cluster(1, 2),
            CommChoice::Auto,
            4,
        )
        .unwrap();
        // Skewed loads: expert 0 hot, everyone else cold.
        for _ in 0..10 {
            r.observe(&[80, 2, 2, 2, 2, 2, 2, 2]);
        }
        let hot = r.hot_experts(1.5);
        assert_eq!(hot, vec![0]);
        let cold = r.cold_experts(0.5);
        assert_eq!(cold, vec![1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn installed_table_moves_experts_and_none_restores_formula() {
        let mut r = PlacementRouter::new(
            cfg(GateKind::Switch),
            cluster(2, 2),
            CommChoice::Auto,
            0,
        )
        .unwrap();
        // Swap experts 0 and 7 relative to the contiguous formula.
        let mut table: Vec<usize> = (0..8).map(|e| e / 2).collect();
        table.swap(0, 7);
        r.set_table(Some(table)).unwrap();
        assert_eq!(r.rank_of_expert(0), 3);
        assert_eq!(r.rank_of_expert(7), 0);
        assert_eq!(r.rank_of_expert(1), 0);
        // A bad table is rejected and leaves the old one installed.
        assert!(r.set_table(Some(vec![9; 8])).is_err());
        assert_eq!(r.rank_of_expert(0), 3);
        r.set_table(None).unwrap();
        assert_eq!(r.rank_of_expert(0), 0);
    }

    #[test]
    fn replica_spread_is_deterministic_and_conserves_tokens() {
        let mk = || {
            let mut r = PlacementRouter::new(
                cfg(GateKind::Switch),
                cluster(2, 2),
                CommChoice::Auto,
                21,
            )
            .unwrap();
            // Expert 0 (primary rank 0) gains a copy on rank 3.
            r.add_replica(0, 3).unwrap();
            r
        };
        let mut a = mk();
        let mut b = mk();
        let mut rng = Rng::seed(17);
        let x = Tensor::randn(&[96, 16], &mut rng);
        let da = a.route_batch(&x, 0);
        let db = b.route_batch(&x, 0);
        // Deterministic: identical routers + batch → identical spread.
        assert_eq!(da.counts, db.counts);
        assert_eq!(da.replicated, db.replicated);
        // Conservation still holds with rows split across copies.
        let matrix_total: usize = da.counts.iter().flatten().sum();
        let expert_total: usize = da.expert_counts.iter().sum();
        assert_eq!(matrix_total, expert_total);
        // Expert 0's rows actually split: with >= 2 routed rows the
        // round-robin puts some on each copy.
        if da.expert_counts[0] >= 2 {
            assert!(da.replicated);
            let col = |dst: usize| -> usize {
                (0..4).map(|src| da.counts[src][dst]).sum()
            };
            // Rank 3 hosts experts 6,7 natively; its column must carry
            // at least one of expert 0's rotated rows on top — compare
            // against a replica-free router on the same batch.
            let mut plain = PlacementRouter::new(
                cfg(GateKind::Switch),
                cluster(2, 2),
                CommChoice::Auto,
                21,
            )
            .unwrap();
            let dp = plain.route_batch(&x, 0);
            assert!(!dp.replicated);
            let plain_col3: usize = (0..4).map(|src| dp.counts[src][3]).sum();
            assert!(
                col(3) > plain_col3,
                "replica copy on rank 3 must absorb rows: {} vs {plain_col3}",
                col(3)
            );
        }
    }

    #[test]
    fn killing_a_replica_holder_degrades_to_surviving_copy() {
        let mut r = PlacementRouter::new(
            cfg(GateKind::Switch),
            cluster(2, 2),
            CommChoice::Auto,
            23,
        )
        .unwrap();
        r.add_replica(0, 3).unwrap();
        assert_eq!(r.replicas().num_replicas(0), 1);
        // Kill the replica holder: the copy vanishes, routing falls
        // back to the primary, and batches keep flowing.
        r.set_dead(&[3]);
        assert_eq!(r.replicas().num_replicas(0), 0);
        let mut rng = Rng::seed(19);
        let x = Tensor::randn(&[48, 16], &mut rng);
        let d = r.route_batch(&x, 0);
        assert!(!d.replicated);
        let kept: usize = d.expert_counts.iter().sum();
        assert!(kept > 0, "routing must continue after the kill");
        // Dead rank receives nothing.
        for src in 0..4 {
            assert_eq!(d.counts[src][3], 0);
        }
        // New replicas cannot target the dead rank.
        assert!(r.add_replica(1, 3).is_err());
        assert!(r.add_replica(1, 2).is_ok());
    }

    #[test]
    fn replica_validation_rejects_primary_and_out_of_range() {
        let mut r = PlacementRouter::new(
            cfg(GateKind::Switch),
            cluster(2, 2),
            CommChoice::Auto,
            29,
        )
        .unwrap();
        assert!(r.add_replica(0, 0).is_err(), "primary rank is not a replica");
        assert!(r.add_replica(8, 1).is_err());
        assert!(r.add_replica(0, 4).is_err());
        assert!(r.add_replica(0, 1).is_ok());
        // Idempotent.
        r.add_replica(0, 1).unwrap();
        assert_eq!(r.replicas().num_replicas(0), 1);
    }

    #[test]
    fn tiny_batches_shard_without_panicking() {
        let mut r = PlacementRouter::new(
            cfg(GateKind::GShard),
            cluster(2, 2),
            CommChoice::Auto,
            6,
        )
        .unwrap();
        let mut rng = Rng::seed(13);
        // Fewer tokens than ranks → some shards are empty.
        let x = Tensor::randn(&[2, 16], &mut rng);
        let d = r.route_batch(&x, 0);
        assert_eq!(d.shards.len(), 4);
        let kept: usize = d.expert_counts.iter().sum();
        assert!(kept >= 2, "top-2 over 2 tokens keeps >= 2 slots, got {kept}");
        assert!(CommChoice::parse("nonsense").is_err());
        assert_eq!(CommChoice::parse("hier").unwrap(), CommChoice::Hierarchical);
    }
}
