//! Continuous batching under expert-capacity and latency budgets.
//!
//! Unlike the training loop's fixed shards, the serving path assembles a
//! fresh token batch every engine iteration from whatever requests are
//! in flight (vLLM-style continuous batching): a request joins the
//! running batch the moment a slot frees up and leaves the moment its
//! last token is processed — no waiting for batch-mates. Admission is
//! bounded two ways:
//!
//! 1. **token budget** (`max_batch_tokens`) — the `E·C` rows the expert
//!    buffers can absorb per iteration without excess drops, as derived
//!    by the engine from the MoE capacity config and the latency budget;
//! 2. **deadlines** — queued requests whose SLO already expired are
//!    dropped before they waste a slot (better to shed than to serve
//!    dead work), and the queue itself is bounded (`max_queue`) so
//!    overload sheds at admission instead of growing unboundedly.

use crate::serve::workload::Request;
use std::collections::VecDeque;

/// Batcher limits (see module docs).
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Max tokens across all requests in one iteration's batch.
    pub max_batch_tokens: usize,
    /// Max tokens a single request contributes per iteration (its
    /// remaining work is carried to later iterations).
    pub chunk_tokens: usize,
    /// Admission-queue bound; arrivals beyond it are rejected.
    pub max_queue: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { max_batch_tokens: 1024, chunk_tokens: 64, max_queue: 4096 }
    }
}

/// A request being served across iterations.
#[derive(Clone, Debug)]
struct Active {
    req: Request,
    remaining: usize,
}

/// Batcher-local counters for tests and diagnostics. The engine's
/// [`crate::serve::slo::SloTracker`] keeps its own request accounting
/// at event time; these are not folded into the SLO report.
#[derive(Clone, Debug, Default)]
pub struct SchedStats {
    pub admitted: usize,
    pub rejected: usize,
    pub expired: usize,
}

/// One iteration's admitted work.
#[derive(Clone, Debug)]
pub struct BatchPlan {
    /// `(request id, tokens contributed this iteration)` in service order.
    pub entries: Vec<(u64, usize)>,
    /// Total tokens in the batch.
    pub tokens: usize,
}

/// The continuous batcher.
pub struct ContinuousBatcher {
    pub cfg: SchedulerConfig,
    queue: VecDeque<Request>,
    active: Vec<Active>,
    pub stats: SchedStats,
}

impl ContinuousBatcher {
    pub fn new(mut cfg: SchedulerConfig) -> ContinuousBatcher {
        // A zero chunk or budget would admit work it can never serve.
        cfg.chunk_tokens = cfg.chunk_tokens.max(1);
        cfg.max_batch_tokens = cfg.max_batch_tokens.max(cfg.chunk_tokens);
        ContinuousBatcher {
            cfg,
            queue: VecDeque::new(),
            active: Vec::new(),
            stats: SchedStats::default(),
        }
    }

    /// Offer an arrival; `false` means the bounded queue rejected it.
    pub fn enqueue(&mut self, req: Request) -> bool {
        if self.queue.len() >= self.cfg.max_queue {
            self.stats.rejected += 1;
            return false;
        }
        self.queue.push_back(req);
        true
    }

    /// Drop queued requests whose deadline has already passed; returns
    /// them so the tracker can account the sheds.
    pub fn expire(&mut self, now: f64) -> Vec<Request> {
        let mut dropped = Vec::new();
        self.queue.retain(|r| {
            if r.deadline < now {
                dropped.push(r.clone());
                false
            } else {
                true
            }
        });
        self.stats.expired += dropped.len();
        dropped
    }

    /// Assemble the next iteration's batch: in-flight requests first
    /// (FCFS by admission), then fresh admissions from the queue while
    /// the token budget holds. `None` when there is nothing to serve.
    ///
    /// Deadline shedding is the caller's job: call [`Self::expire`]
    /// first so every dropped request is accounted for — this method
    /// never discards work silently.
    pub fn next_batch(&mut self) -> Option<BatchPlan> {
        let mut entries = Vec::new();
        let mut tokens = 0usize;
        for a in &self.active {
            if tokens >= self.cfg.max_batch_tokens {
                break; // over-subscribed: the rest waits an iteration
            }
            let take = a
                .remaining
                .min(self.cfg.chunk_tokens)
                .min(self.cfg.max_batch_tokens - tokens);
            if take == 0 {
                continue;
            }
            entries.push((a.req.id, take));
            tokens += take;
        }
        while tokens < self.cfg.max_batch_tokens {
            let Some(req) = self.queue.pop_front() else { break };
            let take = req
                .tokens
                .min(self.cfg.chunk_tokens)
                .min(self.cfg.max_batch_tokens - tokens);
            // `take == 0` here only for a zero-token request (the chunk
            // and remaining budget are both >= 1): admit it anyway so
            // `complete` retires it this iteration instead of letting it
            // block the queue head until its deadline.
            self.stats.admitted += 1;
            entries.push((req.id, take));
            tokens += take;
            self.active.push(Active { remaining: req.tokens, req });
        }
        if entries.is_empty() {
            None
        } else {
            Some(BatchPlan { entries, tokens })
        }
    }

    /// Account a served batch; returns requests that just finished.
    pub fn complete(&mut self, plan: &BatchPlan) -> Vec<Request> {
        // Index once: under overload `active` holds thousands of
        // requests and a per-entry linear scan would dominate the loop.
        let index: std::collections::HashMap<u64, usize> = self
            .active
            .iter()
            .enumerate()
            .map(|(i, a)| (a.req.id, i))
            .collect();
        for &(id, served) in &plan.entries {
            if let Some(&i) = index.get(&id) {
                self.active[i].remaining = self.active[i].remaining.saturating_sub(served);
            }
        }
        let mut finished = Vec::new();
        self.active.retain(|a| {
            if a.remaining == 0 {
                finished.push(a.req.clone());
                false
            } else {
                true
            }
        });
        finished
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    /// Tokens still owed to in-flight requests.
    pub fn in_flight_tokens(&self) -> usize {
        self.active.iter().map(|a| a.remaining).sum()
    }

    /// True when no request is queued or in flight.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival: f64, tokens: usize, slo: f64) -> Request {
        Request { id, arrival, tokens, deadline: arrival + slo }
    }

    fn batcher(max_batch: usize, chunk: usize, max_queue: usize) -> ContinuousBatcher {
        ContinuousBatcher::new(SchedulerConfig {
            max_batch_tokens: max_batch,
            chunk_tokens: chunk,
            max_queue,
        })
    }

    #[test]
    fn admits_fcfs_under_token_budget() {
        let mut b = batcher(100, 64, 16);
        for i in 0..4 {
            assert!(b.enqueue(req(i, 0.0, 40, 1.0)));
        }
        let plan = b.next_batch().unwrap();
        // 40 + 40 admitted, third would overflow to 120 → capped at 20.
        assert_eq!(plan.entries[0], (0, 40));
        assert_eq!(plan.entries[1], (1, 40));
        assert_eq!(plan.entries[2], (2, 20));
        assert_eq!(plan.tokens, 100);
        assert_eq!(b.queue_depth(), 1);
        assert_eq!(b.active_count(), 3);
    }

    #[test]
    fn long_request_is_chunked_across_iterations() {
        let mut b = batcher(256, 32, 16);
        b.enqueue(req(0, 0.0, 100, 1.0));
        let mut iterations = 0;
        let mut finished = Vec::new();
        while let Some(plan) = b.next_batch() {
            assert!(plan.tokens <= 32);
            finished.extend(b.complete(&plan));
            iterations += 1;
            assert!(iterations < 10, "must terminate");
        }
        // ceil(100 / 32) = 4 iterations to drain.
        assert_eq!(iterations, 4);
        assert_eq!(finished.len(), 1);
        assert_eq!(finished[0].id, 0);
        assert!(b.is_idle());
    }

    #[test]
    fn continuous_admission_joins_running_batch() {
        let mut b = batcher(64, 32, 16);
        b.enqueue(req(0, 0.0, 64, 1.0));
        let p1 = b.next_batch().unwrap();
        assert_eq!(p1.entries.len(), 1);
        b.complete(&p1);
        // A new request arrives mid-flight; next batch serves both.
        b.enqueue(req(1, 0.1, 16, 1.0));
        let p2 = b.next_batch().unwrap();
        let ids: Vec<u64> = p2.entries.iter().map(|e| e.0).collect();
        assert_eq!(ids, vec![0, 1], "in-flight first, then fresh admission");
    }

    #[test]
    fn expired_queued_requests_are_shed() {
        let mut b = batcher(64, 32, 16);
        b.enqueue(req(0, 0.0, 16, 0.05)); // deadline 0.05
        b.enqueue(req(1, 0.0, 16, 1.0));
        let dropped = b.expire(0.1);
        assert_eq!(dropped.len(), 1);
        assert_eq!(dropped[0].id, 0);
        assert_eq!(b.stats.expired, 1);
        let plan = b.next_batch().unwrap();
        assert_eq!(plan.entries[0].0, 1);
    }

    #[test]
    fn admitted_requests_run_to_completion_past_deadline() {
        // Deadlines shed queued work only; in-flight requests finish
        // (and get counted as SLO violations by the tracker instead).
        let mut b = batcher(64, 32, 16);
        b.enqueue(req(0, 0.0, 64, 0.01));
        let p1 = b.next_batch().unwrap();
        b.complete(&p1);
        let p2 = b.next_batch().unwrap(); // way past the deadline
        assert_eq!(p2.entries[0].0, 0);
        let finished = b.complete(&p2);
        assert_eq!(finished.len(), 1);
    }

    #[test]
    fn bounded_queue_rejects_overload() {
        let mut b = batcher(64, 32, 2);
        assert!(b.enqueue(req(0, 0.0, 8, 1.0)));
        assert!(b.enqueue(req(1, 0.0, 8, 1.0)));
        assert!(!b.enqueue(req(2, 0.0, 8, 1.0)));
        assert_eq!(b.stats.rejected, 1);
        assert_eq!(b.queue_depth(), 2);
    }

    #[test]
    fn zero_token_request_retires_without_blocking() {
        // A malformed/empty request (e.g. from a hand-written trace)
        // must not camp on the queue head starving later arrivals.
        let mut b = batcher(64, 32, 16);
        b.enqueue(req(0, 0.0, 0, 1.0));
        b.enqueue(req(1, 0.0, 16, 1.0));
        let plan = b.next_batch().unwrap();
        assert_eq!(plan.entries, vec![(0, 0), (1, 16)]);
        let finished = b.complete(&plan);
        assert_eq!(finished.len(), 2, "zero-token request retires immediately");
        assert!(b.is_idle());
    }

    #[test]
    fn degenerate_config_is_clamped() {
        let b = ContinuousBatcher::new(SchedulerConfig {
            max_batch_tokens: 0,
            chunk_tokens: 0,
            max_queue: 4,
        });
        assert_eq!(b.cfg.chunk_tokens, 1);
        assert_eq!(b.cfg.max_batch_tokens, 1);
    }

    #[test]
    fn empty_batcher_yields_no_batch() {
        let mut b = batcher(64, 32, 4);
        assert!(b.next_batch().is_none());
        assert!(b.is_idle());
        assert_eq!(b.in_flight_tokens(), 0);
    }
}
