//! SLO accounting: request latencies, goodput, shed rates, queue depth,
//! and the per-phase time breakdown.
//!
//! The tracker is fed three streams by the engine — request outcomes
//! (completion / shed), queue-depth samples at each iteration, and the
//! per-batch [`StepReport`]s the training pipeline already emits — and
//! folds the last into the coordinator's [`MetricsAgg`], so a serving
//! run produces the same phase breakdown tables as a training run plus
//! the latency distribution on top.

use crate::coordinator::metrics::{Breakdown, MetricsAgg};
use crate::moe::StepReport;
use crate::serve::workload::Request;
use crate::util::json::Json;
use crate::util::stats::{Quantiles, RollingQuantiles};

/// Completed-request window behind the rolling tail-latency numbers
/// (`latency_window_*`): wide enough to make p99 meaningful, narrow
/// enough that end-of-run drift is not averaged away.
pub const LATENCY_WINDOW: usize = 256;

/// A completed request with its observed completion time.
#[derive(Clone, Debug)]
pub struct RequestOutcome {
    pub id: u64,
    pub arrival: f64,
    pub finish: f64,
    pub tokens: usize,
    pub deadline: f64,
}

impl RequestOutcome {
    pub fn latency(&self) -> f64 {
        self.finish - self.arrival
    }

    pub fn on_time(&self) -> bool {
        self.finish <= self.deadline
    }
}

/// Collects everything the final [`SloReport`] needs.
pub struct SloTracker {
    completed: Vec<RequestOutcome>,
    dropped: usize,
    rejected: usize,
    queue_depths: Vec<f64>,
    metrics: MetricsAgg,
    window: RollingQuantiles,
    faults_injected: usize,
    retries: usize,
}

impl Default for SloTracker {
    fn default() -> SloTracker {
        SloTracker {
            completed: Vec::new(),
            dropped: 0,
            rejected: 0,
            queue_depths: Vec::new(),
            metrics: MetricsAgg::new(),
            window: RollingQuantiles::new(LATENCY_WINDOW),
            faults_injected: 0,
            retries: 0,
        }
    }
}

impl SloTracker {
    pub fn new() -> SloTracker {
        SloTracker::default()
    }

    /// Record a request finishing at `finish` (possibly past deadline).
    pub fn complete(&mut self, req: &Request, finish: f64) {
        self.window.push(finish - req.arrival);
        self.completed.push(RequestOutcome {
            id: req.id,
            arrival: req.arrival,
            finish,
            tokens: req.tokens,
            deadline: req.deadline,
        });
    }

    /// Record queued requests shed for missing their deadline.
    pub fn drop_expired(&mut self, n: usize) {
        self.dropped += n;
    }

    /// Record arrivals rejected at admission (bounded queue).
    pub fn reject(&mut self, n: usize) {
        self.rejected += n;
    }

    /// Sample the admission-queue depth (once per engine iteration).
    pub fn sample_queue_depth(&mut self, depth: usize) {
        self.queue_depths.push(depth as f64);
    }

    /// Fold one served batch's phase times into the breakdown.
    pub fn push_step(&mut self, report: &StepReport) {
        self.faults_injected += report.faults_injected;
        self.retries += report.retries;
        self.metrics.push(report);
    }

    /// Record ranks lost mid-run (each counts as one injected fault).
    pub fn record_rank_failures(&mut self, n: usize) {
        self.faults_injected += n;
    }

    pub fn completed_count(&self) -> usize {
        self.completed.len()
    }

    /// Produce the final report for a run of `duration` simulated
    /// seconds.
    pub fn report(&self, duration: f64) -> SloReport {
        let latencies: Vec<f64> = self.completed.iter().map(|o| o.latency()).collect();
        let on_time: Vec<&RequestOutcome> =
            self.completed.iter().filter(|o| o.on_time()).collect();
        let offered = self.completed.len() + self.dropped + self.rejected;
        let dur = duration.max(1e-9);
        let mean_latency = if latencies.is_empty() {
            0.0
        } else {
            latencies.iter().sum::<f64>() / latencies.len() as f64
        };
        let mean_queue = if self.queue_depths.is_empty() {
            0.0
        } else {
            self.queue_depths.iter().sum::<f64>() / self.queue_depths.len() as f64
        };
        let max_queue = self.queue_depths.iter().cloned().fold(0.0, f64::max);
        SloReport {
            duration,
            offered,
            completed: self.completed.len(),
            dropped: self.dropped,
            rejected: self.rejected,
            slo_violations: self.completed.len() - on_time.len(),
            latency: Quantiles::of(&latencies),
            latency_window: self.window.quantiles(),
            latency_window_len: self.window.len(),
            mean_latency,
            goodput_rps: on_time.len() as f64 / dur,
            goodput_tps: on_time.iter().map(|o| o.tokens as f64).sum::<f64>() / dur,
            drop_rate: (self.dropped + self.rejected) as f64 / offered.max(1) as f64,
            mean_queue_depth: mean_queue,
            max_queue_depth: max_queue,
            breakdown: self.metrics.breakdown(),
            batches: self.metrics.steps(),
            faults_injected: self.faults_injected,
            retries: self.retries,
        }
    }
}

/// End-of-run serving report.
#[derive(Clone, Debug)]
pub struct SloReport {
    /// Simulated seconds the run covered.
    pub duration: f64,
    /// Requests that arrived (completed + shed).
    pub offered: usize,
    pub completed: usize,
    /// Queued requests shed for missing their deadline.
    pub dropped: usize,
    /// Arrivals rejected by the bounded admission queue.
    pub rejected: usize,
    /// Completed requests that finished after their deadline.
    pub slo_violations: usize,
    /// Latency distribution over completed requests, seconds.
    pub latency: Quantiles,
    /// Latency distribution over only the last [`LATENCY_WINDOW`]
    /// completions — the "recent tail", sensitive to end-of-run drift.
    pub latency_window: Quantiles,
    /// Completions actually inside the window (< `LATENCY_WINDOW` on
    /// short runs).
    pub latency_window_len: usize,
    pub mean_latency: f64,
    /// On-time completions per simulated second.
    pub goodput_rps: f64,
    /// On-time tokens per simulated second.
    pub goodput_tps: f64,
    /// Shed fraction of offered requests (expired + rejected).
    pub drop_rate: f64,
    pub mean_queue_depth: f64,
    pub max_queue_depth: f64,
    /// Per-phase mean times over served batches (coordinator metrics).
    pub breakdown: Breakdown,
    /// Batches served.
    pub batches: usize,
    /// Injected fault events over the run (stragglers, NIC degradation,
    /// transient failures, rank deaths) — 0 on a healthy run.
    pub faults_injected: usize,
    /// Transient-failure retries charged (capped exponential backoff).
    pub retries: usize,
}

impl SloReport {
    /// Print the operator-facing summary tables.
    pub fn emit(&self) {
        use crate::benchkit::Table;
        use crate::util::stats::fmt_duration;
        let mut t = Table::new(
            &format!(
                "Serving SLO report ({:.2} s simulated, {} batches)",
                self.duration, self.batches
            ),
            &["metric", "value"],
        );
        t.row(vec!["requests offered".into(), self.offered.to_string()]);
        t.row(vec!["completed".into(), self.completed.to_string()]);
        t.row(vec![
            "dropped (deadline) / rejected (queue)".into(),
            format!("{} / {}", self.dropped, self.rejected),
        ]);
        t.row(vec!["SLO violations (late finishes)".into(), self.slo_violations.to_string()]);
        t.row(vec!["latency p50".into(), fmt_duration(self.latency.p50)]);
        t.row(vec!["latency p95".into(), fmt_duration(self.latency.p95)]);
        t.row(vec!["latency p99".into(), fmt_duration(self.latency.p99)]);
        t.row(vec![
            format!("recent p50/p95/p99 (last {})", self.latency_window_len),
            format!(
                "{} / {} / {}",
                fmt_duration(self.latency_window.p50),
                fmt_duration(self.latency_window.p95),
                fmt_duration(self.latency_window.p99)
            ),
        ]);
        t.row(vec!["mean latency".into(), fmt_duration(self.mean_latency)]);
        t.row(vec![
            "goodput".into(),
            format!("{:.0} req/s, {:.0} tok/s", self.goodput_rps, self.goodput_tps),
        ]);
        t.row(vec!["drop rate".into(), format!("{:.3}", self.drop_rate)]);
        t.row(vec![
            "queue depth mean / max".into(),
            format!("{:.1} / {:.0}", self.mean_queue_depth, self.max_queue_depth),
        ]);
        if self.faults_injected > 0 {
            t.row(vec![
                "faults injected / retries".into(),
                format!("{} / {}", self.faults_injected, self.retries),
            ]);
        }
        t.emit(None);
        if !self.breakdown.phases.is_empty() {
            let mut b = Table::new(
                "Per-batch phase breakdown (simulated means)",
                &["phase", "mean/batch", "fraction"],
            );
            for (name, secs) in &self.breakdown.phases {
                b.row(vec![
                    name.clone(),
                    fmt_duration(*secs),
                    format!("{:.1}%", 100.0 * secs / self.breakdown.total.max(1e-12)),
                ]);
            }
            b.emit(None);
        }
    }

    /// JSON export for tooling and EXPERIMENTS appendices, via the
    /// canonical schema module (see `obs::schema`).
    pub fn to_json(&self) -> Json {
        crate::obs::schema::slo_json(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival: f64, tokens: usize, deadline: f64) -> Request {
        Request { id, arrival, tokens, deadline }
    }

    fn step(gate: f64, comm: f64) -> StepReport {
        StepReport {
            wall: vec![("gate".into(), gate), ("expert".into(), 0.5)],
            comm: vec![("alltoall_dispatch".into(), comm)],
            drop_rate: 0.0,
            padding_waste: 0.0,
            expert_counts: vec![],
            aux_loss: 0.0,
            ..Default::default()
        }
    }

    #[test]
    fn report_counts_and_goodput() {
        let mut t = SloTracker::new();
        // Two on-time completions, one late, one shed, one rejected.
        t.complete(&req(0, 0.0, 10, 1.0), 0.5);
        t.complete(&req(1, 0.0, 20, 1.0), 0.9);
        t.complete(&req(2, 0.0, 30, 0.2), 0.8); // late
        t.drop_expired(1);
        t.reject(1);
        t.sample_queue_depth(2);
        t.sample_queue_depth(4);
        let r = t.report(2.0);
        assert_eq!(r.offered, 5);
        assert_eq!(r.completed, 3);
        assert_eq!(r.slo_violations, 1);
        assert!((r.goodput_rps - 1.0).abs() < 1e-12); // 2 on-time / 2 s
        assert!((r.goodput_tps - 15.0).abs() < 1e-12); // (10+20) / 2 s
        assert!((r.drop_rate - 0.4).abs() < 1e-12); // 2 of 5 shed
        assert!((r.mean_queue_depth - 3.0).abs() < 1e-12);
        assert_eq!(r.max_queue_depth, 4.0);
        // p50 over latencies {0.5, 0.9, 0.8}.
        assert!((r.latency.p50 - 0.8).abs() < 1e-12);
    }

    #[test]
    fn breakdown_integrates_with_coordinator_metrics() {
        let mut t = SloTracker::new();
        t.push_step(&step(0.2, 0.4));
        t.push_step(&step(0.4, 0.6));
        let r = t.report(1.0);
        assert_eq!(r.batches, 2);
        let gate = r.breakdown.phases.iter().find(|(n, _)| n == "gate").unwrap().1;
        assert!((gate - 0.3).abs() < 1e-12);
        assert!(r.breakdown.fraction_of(&["alltoall"]) > 0.0);
    }

    #[test]
    fn rolling_window_tracks_recent_latencies() {
        let mut t = SloTracker::new();
        // Fill past the window with fast requests, then a slow tail.
        for i in 0..(LATENCY_WINDOW + 50) {
            t.complete(&req(i as u64, 0.0, 1, 10.0), 0.01);
        }
        for i in 0..LATENCY_WINDOW {
            t.complete(&req(10_000 + i as u64, 0.0, 1, 10.0), 1.0);
        }
        let r = t.report(1.0);
        assert_eq!(r.latency_window_len, LATENCY_WINDOW);
        // The window only sees the slow tail; the whole-run p50 still
        // reflects the fast majority.
        assert!((r.latency_window.p50 - 1.0).abs() < 1e-12);
        assert!(r.latency.p50 < 1.0);
        let j = r.to_json();
        assert!((j.f64_field("latency_window_p99").unwrap() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_tracker_reports_zeros() {
        let r = SloTracker::new().report(1.0);
        assert_eq!(r.offered, 0);
        assert_eq!(r.latency, Quantiles::default());
        assert_eq!(r.goodput_rps, 0.0);
        assert_eq!(r.drop_rate, 0.0);
        let j = r.to_json();
        assert_eq!(j.f64_field("completed").unwrap(), 0.0);
    }
}
