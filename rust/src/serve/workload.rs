//! Open-loop request workloads for the serving engine.
//!
//! Serving a production MoE means surviving traffic you don't control:
//! arrivals keep coming whether or not the system keeps up (open loop).
//! This module generates three request streams on the simulated clock —
//! Poisson (steady), bursty (a two-state modulated Poisson process whose
//! bursts stress the admission queue), and replayable [`Trace`]s so a
//! workload can be captured once and re-served bit-identically across
//! gate/comm configurations.

use crate::error::Result;
use crate::util::json::Json;
use crate::util::rng::{Rng, Zipf};

/// One inference request.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time on the simulated clock, seconds.
    pub arrival: f64,
    /// Total tokens the request needs processed (prompt + decode).
    pub tokens: usize,
    /// Absolute completion deadline (arrival + SLO budget).
    pub deadline: f64,
}

impl Request {
    /// The latency budget this request was admitted with.
    pub fn budget(&self) -> f64 {
        self.deadline - self.arrival
    }
}

/// Arrival process shapes.
#[derive(Clone, Debug)]
pub enum ArrivalProcess {
    /// Memoryless arrivals at a constant mean rate (req/s).
    Poisson { rate: f64 },
    /// Two-state modulated Poisson: `burst_rate` during bursts of mean
    /// length `mean_burst` seconds, `base_rate` during calm phases of
    /// mean length `mean_calm` seconds (all exponentially distributed).
    Bursty { base_rate: f64, burst_rate: f64, mean_burst: f64, mean_calm: f64 },
}

impl ArrivalProcess {
    /// Long-run mean arrival rate, req/s.
    pub fn mean_rate(&self) -> f64 {
        match self {
            ArrivalProcess::Poisson { rate } => *rate,
            ArrivalProcess::Bursty { base_rate, burst_rate, mean_burst, mean_calm } => {
                let total = mean_burst + mean_calm;
                (burst_rate * mean_burst + base_rate * mean_calm) / total
            }
        }
    }
}

/// Deterministic workload generator over the simulated clock.
pub struct WorkloadGen {
    process: ArrivalProcess,
    rng: Rng,
    /// Request-length distribution: Zipf over `[min_tokens, max_tokens]`
    /// so most requests are short with a heavy tail (LM decode shapes).
    lengths: Zipf,
    min_tokens: usize,
    /// Per-request latency SLO, seconds.
    slo: f64,
    clock: f64,
    next_id: u64,
    in_burst: bool,
    phase_end: f64,
}

impl WorkloadGen {
    pub fn new(
        process: ArrivalProcess,
        min_tokens: usize,
        max_tokens: usize,
        slo: f64,
        seed: u64,
    ) -> WorkloadGen {
        let span = max_tokens.saturating_sub(min_tokens) + 1;
        WorkloadGen {
            process,
            rng: Rng::seed(seed ^ 0x5E12),
            lengths: Zipf::new(span, 1.1),
            min_tokens,
            slo,
            clock: 0.0,
            next_id: 0,
            // phase_end starts expired, so the first rate_now() call
            // toggles the state: seeding `in_burst` true makes runs
            // open in a *calm* phase rather than always mid-burst.
            in_burst: true,
            phase_end: 0.0,
        }
    }

    /// Exponential variate with the given rate.
    fn exp(&mut self, rate: f64) -> f64 {
        let u = self.rng.next_f64();
        -(1.0 - u).ln() / rate
    }

    /// Current instantaneous rate; advances the burst phase when the
    /// clock has crossed its boundary.
    fn rate_now(&mut self) -> f64 {
        match self.process.clone() {
            ArrivalProcess::Poisson { rate } => rate,
            ArrivalProcess::Bursty { base_rate, burst_rate, mean_burst, mean_calm } => {
                while self.clock >= self.phase_end {
                    self.in_burst = !self.in_burst;
                    let mean = if self.in_burst { mean_burst } else { mean_calm };
                    let dur = self.exp(1.0 / mean);
                    self.phase_end += dur;
                }
                if self.in_burst {
                    burst_rate
                } else {
                    base_rate
                }
            }
        }
    }

    /// Next request in arrival order.
    pub fn next_request(&mut self) -> Request {
        let rate = self.rate_now();
        self.clock += self.exp(rate);
        let tokens = self.min_tokens + self.lengths.sample(&mut self.rng);
        let id = self.next_id;
        self.next_id += 1;
        Request { id, arrival: self.clock, tokens, deadline: self.clock + self.slo }
    }

    /// All requests arriving strictly before `duration`.
    pub fn generate(&mut self, duration: f64) -> Vec<Request> {
        let mut out = Vec::new();
        loop {
            let r = self.next_request();
            if r.arrival >= duration {
                return out;
            }
            out.push(r);
        }
    }
}

/// A captured arrival sequence, replayable across configurations.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Trace {
    /// (arrival seconds, tokens) in arrival order.
    pub entries: Vec<(f64, usize)>,
}

impl Trace {
    /// Capture a trace from generated requests.
    pub fn from_requests(reqs: &[Request]) -> Trace {
        Trace { entries: reqs.iter().map(|r| (r.arrival, r.tokens)).collect() }
    }

    /// Materialize requests with a (possibly different) SLO budget.
    pub fn requests(&self, slo: f64) -> Vec<Request> {
        self.entries
            .iter()
            .enumerate()
            .map(|(i, &(at, tokens))| Request {
                id: i as u64,
                arrival: at,
                tokens,
                deadline: at + slo,
            })
            .collect()
    }

    /// Serialize for storage next to bench results.
    pub fn to_json(&self) -> Json {
        Json::arr(self.entries.iter().map(|&(at, tokens)| {
            Json::obj(vec![("at", Json::num(at)), ("tokens", Json::num(tokens as f64))])
        }))
    }

    /// Parse a trace written by [`Self::to_json`].
    pub fn from_json(j: &Json) -> Result<Trace> {
        let arr = j
            .as_arr()
            .ok_or_else(|| crate::config_err!("trace must be a JSON array"))?;
        let mut entries = Vec::with_capacity(arr.len());
        for e in arr {
            entries.push((e.f64_field("at")?, e.usize_field("tokens")?));
        }
        Ok(Trace { entries })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_is_approximately_met() {
        let mut gen = WorkloadGen::new(
            ArrivalProcess::Poisson { rate: 1000.0 },
            8,
            64,
            0.05,
            0,
        );
        let reqs = gen.generate(4.0);
        let rate = reqs.len() as f64 / 4.0;
        assert!(
            (rate - 1000.0).abs() < 100.0,
            "empirical rate {rate} for nominal 1000"
        );
        // Arrivals are sorted and deadlines carry the SLO.
        for w in reqs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        assert!(reqs.iter().all(|r| (r.budget() - 0.05).abs() < 1e-12));
        assert!(reqs.iter().all(|r| (8..=64).contains(&r.tokens)));
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        let mk = |seed| {
            WorkloadGen::new(ArrivalProcess::Poisson { rate: 500.0 }, 8, 64, 0.1, seed)
                .generate(1.0)
        };
        assert_eq!(mk(7), mk(7));
        assert_ne!(mk(7), mk(8));
    }

    #[test]
    fn bursty_is_burstier_than_poisson() {
        // Index of dispersion of counts in 50 ms windows: ≈1 for Poisson,
        // substantially larger for the modulated process.
        let dispersion = |process: ArrivalProcess| {
            let reqs = WorkloadGen::new(process, 8, 8, 0.1, 3).generate(10.0);
            let mut bins = vec![0.0f64; 200];
            for r in &reqs {
                bins[(r.arrival / 0.05) as usize % 200] += 1.0;
            }
            let mean = bins.iter().sum::<f64>() / bins.len() as f64;
            let var = bins.iter().map(|c| (c - mean) * (c - mean)).sum::<f64>()
                / bins.len() as f64;
            var / mean
        };
        let poisson = dispersion(ArrivalProcess::Poisson { rate: 1000.0 });
        let bursty = dispersion(ArrivalProcess::Bursty {
            base_rate: 250.0,
            burst_rate: 4000.0,
            mean_burst: 0.05,
            mean_calm: 0.15,
        });
        assert!(bursty > poisson * 2.0, "bursty {bursty:.2} vs poisson {poisson:.2}");
    }

    #[test]
    fn bursty_mean_rate_formula() {
        let p = ArrivalProcess::Bursty {
            base_rate: 100.0,
            burst_rate: 900.0,
            mean_burst: 0.1,
            mean_calm: 0.3,
        };
        assert!((p.mean_rate() - 300.0).abs() < 1e-9);
    }

    #[test]
    fn trace_roundtrips_through_json() {
        let mut gen =
            WorkloadGen::new(ArrivalProcess::Poisson { rate: 200.0 }, 4, 32, 0.05, 1);
        let reqs = gen.generate(0.5);
        let trace = Trace::from_requests(&reqs);
        let parsed = Trace::from_json(&Json::parse(&trace.to_json().dump()).unwrap()).unwrap();
        assert_eq!(parsed.entries.len(), trace.entries.len());
        for (a, b) in trace.entries.iter().zip(&parsed.entries) {
            assert!((a.0 - b.0).abs() < 1e-9);
            assert_eq!(a.1, b.1);
        }
        // Replay with a tighter SLO rewrites deadlines only.
        let replayed = parsed.requests(0.01);
        assert_eq!(replayed.len(), reqs.len());
        for (orig, rep) in reqs.iter().zip(&replayed) {
            assert_eq!(orig.tokens, rep.tokens);
            assert!((rep.budget() - 0.01).abs() < 1e-12);
        }
    }
}
