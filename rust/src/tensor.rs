//! Host-side dense f32 tensor.
//!
//! A deliberately small row-major tensor: the heavy math runs inside XLA
//! artifacts (L2) or the native kernels in [`crate::nn`]; this type is the
//! interchange container the coordinator shuffles between gates, layout
//! transforms and collectives.

use crate::error::{HetuError, Result};
use crate::util::rng::Rng;

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    data: Vec<f32>,
    shape: Vec<usize>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { data: vec![0.0; shape.iter().product()], shape: shape.to_vec() }
    }

    /// Constant-filled tensor.
    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor { data: vec![v; shape.iter().product()], shape: shape.to_vec() }
    }

    /// Tensor from existing data (checks element count).
    pub fn from_vec(data: Vec<f32>, shape: &[usize]) -> Result<Tensor> {
        let expect: usize = shape.iter().product();
        if data.len() != expect {
            return Err(HetuError::Shape(format!(
                "data has {} elements, shape {:?} wants {}",
                data.len(),
                shape,
                expect
            )));
        }
        Ok(Tensor { data, shape: shape.to_vec() })
    }

    /// Standard-normal random tensor.
    pub fn randn(shape: &[usize], rng: &mut Rng) -> Tensor {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.normal_f32()).collect();
        Tensor { data, shape: shape.to_vec() }
    }

    /// Uniform random tensor in `[lo, hi)`.
    pub fn rand_uniform(shape: &[usize], lo: f32, hi: f32, rng: &mut Rng) -> Tensor {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.uniform(lo, hi)).collect();
        Tensor { data, shape: shape.to_vec() }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Number of rows (first dim) for a matrix view.
    pub fn rows(&self) -> usize {
        *self.shape.first().unwrap_or(&0)
    }

    /// Row stride = product of trailing dims.
    pub fn row_len(&self) -> usize {
        self.shape.iter().skip(1).product::<usize>().max(1)
    }

    /// Borrow row `i` (requires ndim ≥ 1).
    pub fn row(&self, i: usize) -> &[f32] {
        let w = self.row_len();
        &self.data[i * w..(i + 1) * w]
    }

    /// Mutably borrow row `i`.
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let w = self.row_len();
        &mut self.data[i * w..(i + 1) * w]
    }

    /// 2-D indexing convenience.
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 2);
        self.data[i * self.shape[1] + j]
    }

    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.ndim(), 2);
        self.data[i * self.shape[1] + j] = v;
    }

    /// Reshape (same element count).
    pub fn reshape(mut self, shape: &[usize]) -> Result<Tensor> {
        let expect: usize = shape.iter().product();
        if expect != self.data.len() {
            return Err(HetuError::Shape(format!(
                "cannot reshape {:?} ({} elems) to {:?} ({} elems)",
                self.shape,
                self.data.len(),
                shape,
                expect
            )));
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Copy rows `lo..hi` into a new tensor.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Tensor {
        let w = self.row_len();
        let mut shape = self.shape.clone();
        shape[0] = hi - lo;
        Tensor { data: self.data[lo * w..hi * w].to_vec(), shape }
    }

    /// Concatenate tensors along axis 0 (trailing dims must match).
    pub fn concat_rows(parts: &[&Tensor]) -> Result<Tensor> {
        if parts.is_empty() {
            return Err(HetuError::Shape("concat of zero tensors".into()));
        }
        let tail = &parts[0].shape[1..];
        let mut rows = 0;
        for p in parts {
            if &p.shape[1..] != tail {
                return Err(HetuError::Shape(format!(
                    "concat tail mismatch: {:?} vs {:?}",
                    &p.shape[1..],
                    tail
                )));
            }
            rows += p.shape[0];
        }
        let mut shape = parts[0].shape.clone();
        shape[0] = rows;
        let mut data = Vec::with_capacity(shape.iter().product());
        for p in parts {
            data.extend_from_slice(&p.data);
        }
        Ok(Tensor { data, shape })
    }

    /// Elementwise maximum absolute difference against another tensor.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Check approximate equality.
    pub fn allclose(&self, other: &Tensor, atol: f32) -> bool {
        self.shape == other.shape && self.max_abs_diff(other) <= atol
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// In-place scale.
    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// In-place add of another tensor.
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Transpose a 2-D tensor.
    pub fn transpose(&self) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = Tensor::zeros(&[n, m]);
        for i in 0..m {
            for j in 0..n {
                out.data[j * m + i] = self.data[i * n + j];
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let t = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.at(0, 1), 2.0);
        assert_eq!(t.at(1, 2), 6.0);
        assert_eq!(t.row(1), &[4.0, 5.0, 6.0]);
        assert!(Tensor::from_vec(vec![1.0], &[2, 3]).is_err());
    }

    #[test]
    fn reshape_checks_count() {
        let t = Tensor::zeros(&[4, 3]);
        assert!(t.clone().reshape(&[3, 4]).is_ok());
        assert!(t.reshape(&[5, 5]).is_err());
    }

    #[test]
    fn slice_and_concat_roundtrip() {
        let mut rng = Rng::seed(0);
        let t = Tensor::randn(&[10, 4], &mut rng);
        let a = t.slice_rows(0, 3);
        let b = t.slice_rows(3, 10);
        let back = Tensor::concat_rows(&[&a, &b]).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn concat_rejects_mismatched_tail() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 4]);
        assert!(Tensor::concat_rows(&[&a, &b]).is_err());
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::seed(1);
        let t = Tensor::randn(&[5, 7], &mut rng);
        assert_eq!(t.transpose().transpose(), t);
        assert_eq!(t.transpose().shape(), &[7, 5]);
        assert_eq!(t.at(2, 3), t.transpose().at(3, 2));
    }

    #[test]
    fn arithmetic_helpers() {
        let mut a = Tensor::full(&[2, 2], 1.0);
        let b = Tensor::full(&[2, 2], 2.0);
        a.add_assign(&b);
        assert_eq!(a, Tensor::full(&[2, 2], 3.0));
        a.scale(0.5);
        assert_eq!(a, Tensor::full(&[2, 2], 1.5));
        assert!((Tensor::full(&[4], 2.0).norm() - 4.0).abs() < 1e-6);
    }

    #[test]
    fn allclose_and_diff() {
        let a = Tensor::full(&[3], 1.0);
        let mut b = a.clone();
        b.data_mut()[1] = 1.0005;
        assert!(a.allclose(&b, 1e-3));
        assert!(!a.allclose(&b, 1e-4));
        assert!((a.max_abs_diff(&b) - 0.0005).abs() < 1e-6);
    }

    #[test]
    fn randn_is_seeded() {
        let mut r1 = Rng::seed(9);
        let mut r2 = Rng::seed(9);
        assert_eq!(Tensor::randn(&[8, 8], &mut r1), Tensor::randn(&[8, 8], &mut r2));
    }
}
