//! End-to-end training.
//!
//! Two backends:
//!
//! - **Native (default)** — the pure-Rust backward pass, Adam and
//!   training loop in [`crate::backprop`] ([`NativeTrainer`]). Runs the
//!   full Algorithm-1 pipeline forward *and* backward on the simulated
//!   cluster with no external toolchain; this is what the `train`
//!   subcommand drives.
//! - **PJRT artifacts** (feature `pjrt`) — the AOT-compiled XLA path:
//!   `python/compile/aot.py` lowers `<model>_init(seed) → params…` and
//!   `<model>_step(params…, tokens, targets) → (params…, loss)` once;
//!   [`Trainer`] loops the fused step executable. Python is never
//!   involved at run time. Still gated because the `xla` crate needs an
//!   XLA toolchain at link time (the offline stub only compiles).

#[cfg(feature = "pjrt")]
pub mod trainer;

#[cfg(feature = "pjrt")]
pub use trainer::{TrainLog, Trainer};

pub use crate::backprop::{
    smoothed_losses, NativeTrainer, TrainRunConfig, TrainStepLog, TrainSummary,
};
