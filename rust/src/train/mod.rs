//! End-to-end training over the AOT artifacts (L2/L1 compute).
//!
//! `python/compile/aot.py` lowers two functions per model variant:
//! - `<model>_init(seed) → params…` — parameter initialization;
//! - `<model>_step(params…, tokens, targets) → (params…, loss)` — one
//!   fused forward/backward/Adam step.
//!
//! The trainer loads both once, keeps parameters as host literals, and
//! loops: feed params + batch → receive new params + loss. Python is
//! never involved at run time.

pub mod trainer;

pub use trainer::{TrainLog, Trainer};
