//! The artifact-driven training loop.
//!
//! NOTE on the execution path: the published `xla` crate's
//! `PjRtLoadedExecutable::execute(&[Literal])` **leaks every input
//! device buffer** (its C shim `release()`s the uploaded buffers and
//! never frees them), which at ~1.3 GB of parameters per step OOMs a
//! 100M-param run within ~25 steps. The trainer therefore uploads
//! inputs itself (`buffer_from_host_buffer` → owned `PjRtBuffer`s with
//! correct `Drop`) and runs `execute_b`, which only borrows them.

use crate::comm::F32_BYTES;
use crate::config::TrainConfig;
use crate::data::{BatchIter, SyntheticLm};
use crate::error::{HetuError, Result};
use crate::runtime::{HloRunner, RuntimeClient};
use std::sync::Arc;
use std::time::Instant;

/// Per-step record of the run.
#[derive(Clone, Debug)]
pub struct TrainLog {
    pub step: u64,
    pub loss: f32,
    pub wall: f64,
}

/// One flat state tensor (host side).
struct HostParam {
    data: Vec<f32>,
    dims: Vec<usize>,
}

/// Loads `<model>_init` / `<model>_step` artifacts and trains.
pub struct Trainer {
    pub runtime: RuntimeClient,
    step: Arc<HloRunner>,
    pub cfg: TrainConfig,
    /// Flat training state (params + optimizer state), fed back each step.
    params: Vec<HostParam>,
    pub vocab: usize,
    pub logs: Vec<TrainLog>,
}

impl Trainer {
    /// Load artifacts for `cfg.model` and initialize parameters.
    ///
    /// Batch geometry (batch size / sequence length) is static in the
    /// compiled artifact, so the trainer adopts the artifact's values.
    pub fn new(mut cfg: TrainConfig) -> Result<Trainer> {
        let mut runtime = RuntimeClient::cpu(&cfg.artifact_dir)?;
        let init = runtime.runner(&format!("{}_init", cfg.model))?;
        let step = runtime.runner(&format!("{}_step", cfg.model))?;
        let vocab = step.meta.attr_usize("vocab")?;
        cfg.batch_size = step.meta.attr_usize("batch")?;
        cfg.seq_len = step.meta.attr_usize("seq")?;

        // Run init(seed) once through execute_b.
        let seed_buf = runtime
            .client
            .buffer_from_host_buffer(&[cfg.seed as i32], &[], None)?;
        let out = init.run_buffers(&[seed_buf])?;
        let lits = out.to_literal_sync()?.to_tuple()?;
        let params: Vec<HostParam> = lits
            .into_iter()
            .zip(&step.meta.inputs)
            .map(|(lit, dims)| {
                Ok(HostParam { data: lit.to_vec::<f32>()?, dims: dims.clone() })
            })
            .collect::<Result<_>>()?;
        if params.len() + 2 != step.meta.inputs.len() {
            return Err(HetuError::Artifact(format!(
                "init returned {} params but step wants {} inputs (params + tokens + targets)",
                params.len(),
                step.meta.inputs.len()
            )));
        }
        Ok(Trainer { runtime, step, cfg, params, vocab, logs: Vec::new() })
    }

    /// Number of parameter tensors.
    pub fn num_param_tensors(&self) -> usize {
        self.params.len()
    }

    /// Total state element count.
    pub fn num_params(&self) -> usize {
        self.params.iter().map(|p| p.data.len()).sum()
    }

    /// One training step on an (inputs, targets) token batch.
    pub fn train_step(&mut self, tokens: &[u32], targets: &[u32]) -> Result<f32> {
        let n = self.cfg.batch_size * self.cfg.seq_len;
        if tokens.len() != n || targets.len() != n {
            return Err(crate::shape_err!(
                "batch must be {n} tokens, got {}/{}",
                tokens.len(),
                targets.len()
            ));
        }
        let client = &self.runtime.client;
        let dims = [self.cfg.batch_size, self.cfg.seq_len];
        let tok_i32: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let tgt_i32: Vec<i32> = targets.iter().map(|&t| t as i32).collect();

        // Upload the whole state + batch as owned device buffers.
        let mut bufs = Vec::with_capacity(self.params.len() + 2);
        for p in &self.params {
            bufs.push(client.buffer_from_host_buffer(&p.data, &p.dims, None)?);
        }
        bufs.push(client.buffer_from_host_buffer(&tok_i32, &dims, None)?);
        bufs.push(client.buffer_from_host_buffer(&tgt_i32, &dims, None)?);

        let out = self.step.run_buffers(&bufs)?;
        drop(bufs); // inputs freed here (execute_b only borrows)
        let mut parts = out.to_literal_sync()?.to_tuple()?;
        // Convention: last tuple element is the scalar loss.
        let loss_lit = parts.pop().ok_or_else(|| {
            HetuError::Artifact("step artifact returned empty tuple".into())
        })?;
        let loss = loss_lit.get_first_element::<f32>()?;
        for (p, lit) in self.params.iter_mut().zip(parts) {
            p.data = lit.to_vec::<f32>()?;
        }
        Ok(loss)
    }

    /// Save the full training state (params + optimizer) to a binary
    /// checkpoint: a JSON header (tensor dims, model name) followed by
    /// raw little-endian f32 data.
    pub fn save_checkpoint(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        use std::io::Write;
        let header = crate::util::json::Json::obj(vec![
            ("model", crate::util::json::Json::str(self.cfg.model.clone())),
            ("vocab", crate::util::json::Json::num(self.vocab as f64)),
            (
                "tensors",
                crate::util::json::Json::arr(self.params.iter().map(|p| {
                    crate::util::json::Json::arr(
                        p.dims.iter().map(|&d| crate::util::json::Json::num(d as f64)),
                    )
                })),
            ),
        ])
        .dump();
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for p in &self.params {
            for v in &p.data {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    /// Restore training state from [`Self::save_checkpoint`] output.
    /// The checkpoint must match the loaded artifact's tensor layout.
    pub fn load_checkpoint(&mut self, path: impl AsRef<std::path::Path>) -> Result<()> {
        use std::io::Read;
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut len8 = [0u8; 8];
        f.read_exact(&mut len8)?;
        let hlen = u64::from_le_bytes(len8) as usize;
        let mut hbytes = vec![0u8; hlen];
        f.read_exact(&mut hbytes)?;
        let header = crate::util::json::Json::parse(
            std::str::from_utf8(&hbytes)
                .map_err(|_| HetuError::Artifact("bad checkpoint header".into()))?,
        )?;
        if header.str_field("model")? != self.cfg.model {
            return Err(HetuError::Config(format!(
                "checkpoint is for model '{}', trainer loaded '{}'",
                header.str_field("model")?,
                self.cfg.model
            )));
        }
        let dims = header.req("tensors")?.as_arr().ok_or_else(|| {
            HetuError::Artifact("checkpoint header missing tensors".into())
        })?;
        if dims.len() != self.params.len() {
            return Err(HetuError::Artifact(format!(
                "checkpoint has {} tensors, artifact wants {}",
                dims.len(),
                self.params.len()
            )));
        }
        for p in self.params.iter_mut() {
            let mut bytes = vec![0u8; p.data.len() * F32_BYTES];
            f.read_exact(&mut bytes)?;
            for (i, v) in p.data.iter_mut().enumerate() {
                let at = i * F32_BYTES;
                *v = f32::from_le_bytes(bytes[at..at + F32_BYTES].try_into().unwrap());
            }
        }
        Ok(())
    }

    /// Full training run over synthetic data; returns the loss log.
    pub fn run(&mut self) -> Result<Vec<TrainLog>> {
        let task = SyntheticLm::new(self.vocab, 1.1, 0.85);
        let mut batches = BatchIter::new(
            task,
            self.cfg.batch_size,
            self.cfg.seq_len,
            self.cfg.seed ^ 0xDA7A,
        );
        for step in 0..self.cfg.steps {
            let (x, y) = batches.next_batch();
            let t0 = Instant::now();
            let loss = self.train_step(&x, &y)?;
            let wall = t0.elapsed().as_secs_f64();
            if !loss.is_finite() {
                return Err(HetuError::Runtime(format!(
                    "loss diverged (NaN/inf) at step {step}"
                )));
            }
            self.logs.push(TrainLog { step, loss, wall });
            if step % self.cfg.log_every == 0 {
                eprintln!("step {step:>5}  loss {loss:.4}  ({wall:.3}s)");
            }
        }
        Ok(self.logs.clone())
    }
}

// Tests live in rust/tests/integration.rs (need built artifacts).
