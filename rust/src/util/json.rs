//! Minimal JSON parser / writer.
//!
//! `serde`/`serde_json` are not vendored in this build environment, so the
//! config system, artifact metadata and metrics dumps use this small,
//! dependency-free implementation. It supports the full JSON grammar
//! (strings with escapes, numbers, arrays, objects, literals) with
//! insertion-ordered objects.

use crate::error::{HetuError, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Objects keep insertion order (important for stable artifact metadata).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(HetuError::Json(format!(
                "trailing characters at byte {}",
                p.pos
            )));
        }
        Ok(v)
    }

    /// Parse the JSON document in file `path`.
    pub fn from_file(path: impl AsRef<std::path::Path>) -> Result<Json> {
        let text = std::fs::read_to_string(path.as_ref()).map_err(|e| {
            HetuError::Json(format!("{}: {e}", path.as_ref().display()))
        })?;
        Json::parse(&text)
    }

    // ---- constructors ----

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ---- accessors ----

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Object field lookup that errors with the key name (for configs).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| HetuError::Json(format!("missing required field '{key}'")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Typed field helpers (error messages carry the key).
    pub fn f64_field(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| HetuError::Json(format!("field '{key}' must be a number")))
    }

    pub fn usize_field(&self, key: &str) -> Result<usize> {
        self.req(key)?.as_usize().ok_or_else(|| {
            HetuError::Json(format!("field '{key}' must be a non-negative integer"))
        })
    }

    pub fn str_field(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| HetuError::Json(format!("field '{key}' must be a string")))
    }

    /// Optional typed lookups with defaults.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Json::as_usize).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Json::as_bool).unwrap_or(default)
    }

    /// Object → map view (for iteration in key order).
    pub fn as_map(&self) -> Option<BTreeMap<&str, &Json>> {
        match self {
            Json::Obj(pairs) => {
                Some(pairs.iter().map(|(k, v)| (k.as_str(), v)).collect())
            }
            _ => None,
        }
    }

    // ---- serialization ----

    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !pairs.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> HetuError {
        HetuError::Json(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            pairs.push((key, v));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(pairs)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else {
                            s.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode the UTF-8 sequence starting at c.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn string_escapes_roundtrip() {
        let original = Json::Str("a\"b\\c\nd\te\u{1F600}\u{7}".into());
        let text = original.dump();
        assert_eq!(Json::parse(&text).unwrap(), original);
    }

    #[test]
    fn unicode_escape_and_surrogates() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".into())
        );
        assert_eq!(
            Json::parse(r#""😀""#).unwrap(),
            Json::Str("😀".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("{'a':1}").is_err());
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let v = Json::obj(vec![
            ("model", Json::str("moe")),
            ("experts", Json::num(16)),
            ("lr", Json::num(0.001)),
            ("layers", Json::arr((0..4).map(|i| Json::num(i as f64)))),
            ("nested", Json::obj(vec![("ok", Json::Bool(true))])),
        ]);
        for text in [v.dump(), v.pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn typed_field_helpers() {
        let v = Json::parse(r#"{"n": 8, "f": 1.5, "s": "x", "b": true}"#).unwrap();
        assert_eq!(v.usize_field("n").unwrap(), 8);
        assert_eq!(v.f64_field("f").unwrap(), 1.5);
        assert_eq!(v.str_field("s").unwrap(), "x");
        assert!(v.bool_or("b", false));
        assert_eq!(v.usize_or("missing", 3), 3);
        assert!(v.usize_field("missing").is_err());
        assert!(v.usize_field("f").is_err()); // 1.5 is not an integer
    }

    #[test]
    fn preserves_object_order() {
        let v = Json::parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        if let Json::Obj(pairs) = &v {
            let keys: Vec<&str> = pairs.iter().map(|(k, _)| k.as_str()).collect();
            assert_eq!(keys, vec!["z", "a", "m"]);
        } else {
            panic!("not an object");
        }
    }

    #[test]
    fn integer_formatting_has_no_decimal_point() {
        assert_eq!(Json::num(5.0).dump(), "5");
        assert_eq!(Json::num(5.25).dump(), "5.25");
    }
}
