//! Self-contained substrates the offline build environment forced us to own:
//! PRNG (`rand` is unavailable), JSON (`serde` is unavailable), a thread
//! pool (`tokio`/`rayon` are unavailable), summary statistics, and a tiny
//! property-testing kit (`proptest` is unavailable).

pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod threadpool;
