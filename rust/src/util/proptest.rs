//! Mini property-based testing kit.
//!
//! The `proptest` crate is not vendored in this environment; this module
//! provides the subset the test suite needs: seeded generators, a case
//! runner that reports the failing input, and a greedy shrink pass for
//! `Vec`-shaped inputs.
//!
//! Usage (`no_run`: doctest binaries don't inherit the xla rpath):
//! ```no_run
//! use hetumoe::util::proptest::{for_all, Gen};
//! for_all(64, |g| {
//!     let xs = g.vec_u32(0..100, 0..64);
//!     let mut sorted = xs.clone();
//!     sorted.sort_unstable();
//!     assert!(sorted.windows(2).all(|w| w[0] <= w[1]));
//! });
//! ```

use crate::util::rng::Rng;

/// Generator handle passed to properties; wraps a seeded [`Rng`] with
/// convenience samplers.
pub struct Gen {
    rng: Rng,
    /// Case index (exposed so properties can scale sizes).
    pub case: usize,
}

impl Gen {
    /// Raw RNG access.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// usize in `[lo, hi)`.
    pub fn usize_in(&mut self, range: std::ops::Range<usize>) -> usize {
        self.rng.range(range.start, range.end.max(range.start + 1))
    }

    /// u32 in `[lo, hi)`.
    pub fn u32_in(&mut self, range: std::ops::Range<u32>) -> u32 {
        self.rng.range(range.start as usize, range.end.max(range.start + 1) as usize) as u32
    }

    /// f32 in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform(lo, hi)
    }

    /// Standard normal f32.
    pub fn normal(&mut self) -> f32 {
        self.rng.normal_f32()
    }

    /// Bool with probability `p` of true.
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.rng.next_f64() < p
    }

    /// Vec of u32 values with length drawn from `len` and values from `val`.
    pub fn vec_u32(
        &mut self,
        val: std::ops::Range<u32>,
        len: std::ops::Range<usize>,
    ) -> Vec<u32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.u32_in(val.clone())).collect()
    }

    /// Vec of f32 normals with length drawn from `len`.
    pub fn vec_normal(&mut self, len: std::ops::Range<usize>) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.normal()).collect()
    }

    /// Pick one of the provided choices.
    pub fn choose<'a, T>(&mut self, options: &'a [T]) -> &'a T {
        &options[self.rng.below(options.len())]
    }
}

/// Run `prop` over `cases` seeded generator instances. Panics (with the
/// case seed) on the first failing case so it can be replayed with
/// [`replay`].
pub fn for_all<F: FnMut(&mut Gen)>(cases: usize, mut prop: F) {
    for case in 0..cases {
        let seed = derive_seed(case);
        let mut g = Gen { rng: Rng::seed(seed), case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut g);
        }));
        if let Err(payload) = result {
            let msg = panic_message(&payload);
            panic!(
                "property failed on case {case} (seed {seed:#x}): {msg}\n\
                 replay with hetumoe::util::proptest::replay({seed:#x}, prop)"
            );
        }
    }
}

/// Re-run a property with a specific seed (for debugging a failure).
pub fn replay<F: FnMut(&mut Gen)>(seed: u64, mut prop: F) {
    let mut g = Gen { rng: Rng::seed(seed), case: 0 };
    prop(&mut g);
}

/// Derive a per-case seed (stable across runs — deterministic CI).
fn derive_seed(case: usize) -> u64 {
    crate::util::rng::hash_u64(0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15))
}

fn panic_message(payload: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".into()
    }
}

/// Greedy shrink for vector inputs: given a failing input and a predicate
/// `fails`, repeatedly try dropping halves/elements while the predicate
/// still fails. Returns a (locally) minimal failing input.
pub fn shrink_vec<T: Clone, F: Fn(&[T]) -> bool>(input: &[T], fails: F) -> Vec<T> {
    let mut cur: Vec<T> = input.to_vec();
    if !fails(&cur) {
        return cur;
    }
    loop {
        let mut progressed = false;
        // Try removing contiguous chunks, halving sizes.
        let mut chunk = (cur.len() / 2).max(1);
        while chunk >= 1 {
            let mut i = 0;
            while i + chunk <= cur.len() {
                let mut candidate = Vec::with_capacity(cur.len() - chunk);
                candidate.extend_from_slice(&cur[..i]);
                candidate.extend_from_slice(&cur[i + chunk..]);
                if fails(&candidate) {
                    cur = candidate;
                    progressed = true;
                } else {
                    i += chunk;
                }
            }
            if chunk == 1 {
                break;
            }
            chunk /= 2;
        }
        if !progressed {
            return cur;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_all_runs_all_cases() {
        let mut count = 0;
        for_all(32, |_| count += 1);
        assert_eq!(count, 32);
    }

    #[test]
    fn for_all_seeds_are_deterministic() {
        let mut first: Vec<u64> = Vec::new();
        for_all(8, |g| first.push(g.rng().next_u64()));
        let mut second: Vec<u64> = Vec::new();
        for_all(8, |g| second.push(g.rng().next_u64()));
        assert_eq!(first, second);
    }

    #[test]
    #[should_panic(expected = "property failed on case")]
    fn failing_property_reports_seed() {
        for_all(16, |g| {
            let v = g.usize_in(0..10);
            assert!(v < 100); // passes
            if g.case == 7 {
                panic!("intentional");
            }
        });
    }

    #[test]
    fn generators_respect_ranges() {
        for_all(64, |g| {
            let u = g.usize_in(3..9);
            assert!((3..9).contains(&u));
            let xs = g.vec_u32(5..8, 0..20);
            assert!(xs.len() < 20);
            assert!(xs.iter().all(|&x| (5..8).contains(&x)));
            let f = g.f32_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        });
    }

    #[test]
    fn shrink_finds_minimal_counterexample() {
        // Property: no element equals 42. Failing input contains some.
        let input: Vec<u32> = (0..100).map(|i| if i % 17 == 0 { 42 } else { i }).collect();
        let minimal = shrink_vec(&input, |xs| xs.iter().any(|&x| x == 42));
        assert_eq!(minimal, vec![42]);
    }

    #[test]
    fn shrink_non_failing_returns_input() {
        let input = vec![1u32, 2, 3];
        let out = shrink_vec(&input, |_| false);
        assert_eq!(out, input);
    }
}
