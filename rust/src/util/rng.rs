//! Deterministic pseudo-random number generation.
//!
//! The `rand` crate is not vendored in this environment, so we implement
//! the standard small generators ourselves: SplitMix64 for seeding /
//! hashing, xoshiro256** as the workhorse stream, Box–Muller for normals
//! and a rejection-free Zipf sampler for synthetic token streams.

/// SplitMix64 step — also usable as a cheap integer hash.
///
/// Reference: Steele, Lea, Flood — "Fast splittable pseudorandom number
/// generators" (OOPSLA 2014).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Stateless integer hash built on the SplitMix64 finalizer. Used by the
/// Hash-layer gate so token→expert mappings are reproducible.
#[inline]
pub fn hash_u64(x: u64) -> u64 {
    let mut s = x;
    splitmix64(&mut s)
}

/// Full serializable state of an [`Rng`] stream. Restoring it resumes
/// the stream mid-sequence, including the cached Box–Muller spare —
/// required for checkpoint/restore to replay training bit-identically.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RngState {
    pub s: [u64; 4],
    pub gauss_spare: Option<f64>,
}

/// xoshiro256** by Blackman & Vigna — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from Box–Muller.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Snapshot the full generator state for checkpointing.
    pub fn state(&self) -> RngState {
        RngState { s: self.s, gauss_spare: self.gauss_spare }
    }

    /// Rebuild a generator mid-stream from a [`RngState`] snapshot.
    pub fn from_state(state: RngState) -> Self {
        Rng { s: state.s, gauss_spare: state.gauss_spare }
    }

    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn seed(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` (Lemire's multiply-shift, unbiased enough
    /// for simulation purposes).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Uniform float in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Standard normal as f32.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Sample from Gumbel(0, 1): `-ln(-ln(U))`. Used by the
    /// Dense-to-Sparse gate.
    #[inline]
    pub fn gumbel(&mut self) -> f32 {
        let u = self.next_f64().max(1e-12);
        (-(-(u.ln())).max(1e-12).ln()) as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index proportional to `weights` (must be non-negative,
    /// not all zero).
    pub fn weighted(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w as f64).sum();
        let mut t = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w as f64;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Zipf(s) sampler over `{0, .., n-1}` using the precomputed CDF.
/// Synthetic token streams use this so expert load imbalance resembles
/// natural-language token frequencies.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build a sampler over `n` items with exponent `s` (s=1.0 ≈ natural
    /// text).
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Draw one sample (rank 0 = most frequent).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        // Binary search for the first cdf entry >= u.
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap_or(std::cmp::Ordering::Less))
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::seed(42);
        let mut b = Rng::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed(1);
        let mut b = Rng::seed(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Rng::seed(7);
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::seed(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::seed(11);
        let n = 50_000;
        let (mut sum, mut sum2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let z = rng.normal();
            sum += z;
            sum2 += z * z;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn gumbel_mean_is_euler_mascheroni() {
        let mut rng = Rng::seed(13);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| rng.gumbel() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5772).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::seed(5);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // overwhelmingly likely
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let z = Zipf::new(100, 1.1);
        let mut rng = Rng::seed(9);
        let mut counts = [0usize; 100];
        for _ in 0..20_000 {
            let s = z.sample(&mut rng);
            assert!(s < 100);
            counts[s] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > 20_000 / 20); // head is heavy
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut rng = Rng::seed(17);
        let w = [0.0f32, 1.0, 9.0];
        let mut c = [0usize; 3];
        for _ in 0..5000 {
            c[rng.weighted(&w)] += 1;
        }
        assert_eq!(c[0], 0);
        assert!(c[2] > c[1] * 5);
    }

    #[test]
    fn hash_u64_stable() {
        assert_eq!(hash_u64(0), hash_u64(0));
        assert_ne!(hash_u64(1), hash_u64(2));
    }

    /// Mid-stream state round-trip must resume the exact sequence —
    /// including the Box–Muller spare, which `normal()` caches across
    /// calls (an odd number of normals before the snapshot exercises it).
    #[test]
    fn state_round_trip_resumes_mid_stream() {
        let mut a = Rng::seed(23);
        for _ in 0..7 {
            a.next_u64();
        }
        a.normal(); // leaves a spare cached
        let snap = a.state();
        let mut b = Rng::from_state(snap);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        assert_eq!(a.normal(), b.normal());
        assert_eq!(a.normal(), b.normal());
        assert_eq!(a.state(), b.state());
    }
}
