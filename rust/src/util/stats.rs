//! Summary statistics for benchmarks and load-balance diagnostics.

/// Online mean/variance (Welford's algorithm).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile of an **already-sorted** sample (linear interpolation,
/// like numpy's default). `q` in `[0, 100]`. The single definition
/// behind [`percentile`], [`Quantiles`] and [`Summary`].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Percentile over an unsorted sample. Sorts a copy; fine for
/// bench-sized samples (callers taking several quantiles should sort
/// once and use [`percentile_sorted`]).
pub fn percentile(samples: &[f64], q: f64) -> f64 {
    assert!(!samples.is_empty());
    let mut v = samples.to_vec();
    v.sort_by(f64::total_cmp);
    percentile_sorted(&v, q)
}

/// Median absolute deviation (robust spread), scaled for normal consistency.
pub fn mad(samples: &[f64]) -> f64 {
    let med = percentile(samples, 50.0);
    let devs: Vec<f64> = samples.iter().map(|x| (x - med).abs()).collect();
    1.4826 * percentile(&devs, 50.0)
}

/// Full summary of a sample.
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        let mut w = Welford::new();
        for &x in samples {
            w.push(x);
        }
        let mut sorted = samples.to_vec();
        sorted.sort_by(f64::total_cmp);
        Summary {
            n: samples.len(),
            mean: w.mean(),
            std: w.std(),
            min: w.min(),
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p99: percentile_sorted(&sorted, 99.0),
            max: w.max(),
        }
    }
}

/// Tail-latency quantiles of a sample (the serving SLO set). One sort,
/// shared by the SLO engine, the serving bench and the CLI so every
/// surface reports identical numbers for identical samples.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Quantiles {
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Quantiles {
    /// Quantiles of `samples`; all-zero when the sample is empty.
    pub fn of(samples: &[f64]) -> Quantiles {
        if samples.is_empty() {
            return Quantiles::default();
        }
        let mut v = samples.to_vec();
        v.sort_by(f64::total_cmp);
        Quantiles {
            p50: percentile_sorted(&v, 50.0),
            p90: percentile_sorted(&v, 90.0),
            p95: percentile_sorted(&v, 95.0),
            p99: percentile_sorted(&v, 99.0),
        }
    }
}

/// Rolling window over the last `cap` observations, reporting
/// [`Quantiles`] of the window. The serving engine keeps one per run so
/// `serve --json` and the fig9 metrics entry can report tail latency
/// over the *recent* requests instead of only the end-of-run
/// distribution (a drifting p99 is invisible in the whole-run number).
#[derive(Clone, Debug)]
pub struct RollingQuantiles {
    cap: usize,
    buf: std::collections::VecDeque<f64>,
}

impl RollingQuantiles {
    /// A window holding at most `cap` samples (`cap` ≥ 1).
    pub fn new(cap: usize) -> RollingQuantiles {
        assert!(cap >= 1, "window capacity must be at least 1");
        RollingQuantiles { cap, buf: std::collections::VecDeque::with_capacity(cap) }
    }

    /// Add a sample, evicting the oldest once the window is full.
    pub fn push(&mut self, x: f64) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back(x);
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Quantiles of the current window; all-zero when empty.
    pub fn quantiles(&self) -> Quantiles {
        let v: Vec<f64> = self.buf.iter().copied().collect();
        Quantiles::of(&v)
    }
}

/// Coefficient of variation of per-expert loads — the standard MoE
/// load-balance metric (0 = perfectly balanced).
pub fn load_cv(counts: &[usize]) -> f64 {
    if counts.is_empty() {
        return 0.0;
    }
    let mut w = Welford::new();
    for &c in counts {
        w.push(c as f64);
    }
    if w.mean() == 0.0 {
        0.0
    } else {
        w.std() / w.mean()
    }
}

/// Shannon entropy (nats) of a count distribution, normalized to `[0,1]`
/// by `ln(n)`. 1 = uniform routing.
pub fn normalized_entropy(counts: &[usize]) -> f64 {
    let total: usize = counts.iter().sum();
    if total == 0 || counts.len() < 2 {
        return 0.0;
    }
    let mut h = 0.0f64;
    for &c in counts {
        if c > 0 {
            let p = c as f64 / total as f64;
            h -= p * p.ln();
        }
    }
    h / (counts.len() as f64).ln()
}

/// Pretty duration formatting for bench tables.
pub fn fmt_duration(seconds: f64) -> String {
    if seconds >= 1.0 {
        format!("{seconds:.3} s")
    } else if seconds >= 1e-3 {
        format!("{:.3} ms", seconds * 1e3)
    } else if seconds >= 1e-6 {
        format!("{:.3} µs", seconds * 1e6)
    } else {
        format!("{:.1} ns", seconds * 1e9)
    }
}

/// Pretty byte-size formatting.
pub fn fmt_bytes(bytes: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert_eq!(w.count(), 5);
        assert!((w.mean() - 3.0).abs() < 1e-12);
        assert!((w.var() - 2.5).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 5.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let xs = [1.0, 1.1, 0.9, 1.0, 100.0];
        assert!(mad(&xs) < 1.0);
    }

    #[test]
    fn summary_fields_consistent() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert!(s.min <= s.p50 && s.p50 <= s.p90 && s.p90 <= s.p99 && s.p99 <= s.max);
    }

    #[test]
    fn quantiles_match_percentile_and_order() {
        let xs: Vec<f64> = (1..=200).map(|i| i as f64).collect();
        let q = Quantiles::of(&xs);
        assert_eq!(q.p50, percentile(&xs, 50.0));
        assert_eq!(q.p95, percentile(&xs, 95.0));
        assert!(q.p50 <= q.p90 && q.p90 <= q.p95 && q.p95 <= q.p99);
        assert_eq!(Quantiles::of(&[]), Quantiles::default());
        let one = Quantiles::of(&[7.5]);
        assert_eq!(one.p50, 7.5);
        assert_eq!(one.p99, 7.5);
    }

    #[test]
    fn rolling_quantiles_evict_oldest() {
        let mut w = RollingQuantiles::new(4);
        assert!(w.is_empty());
        assert_eq!(w.quantiles(), Quantiles::default());
        for x in [100.0, 1.0, 2.0, 3.0, 4.0] {
            w.push(x);
        }
        // The 100.0 outlier fell out of the window.
        assert_eq!(w.len(), 4);
        assert_eq!(w.capacity(), 4);
        let q = w.quantiles();
        assert_eq!(q, Quantiles::of(&[1.0, 2.0, 3.0, 4.0]));
        assert!(q.p99 <= 4.0);
    }

    #[test]
    fn rolling_quantiles_partial_window() {
        let mut w = RollingQuantiles::new(8);
        w.push(5.0);
        w.push(7.0);
        assert_eq!(w.len(), 2);
        assert_eq!(w.quantiles().p50, 6.0);
    }

    #[test]
    fn load_cv_zero_when_balanced() {
        assert!(load_cv(&[10, 10, 10, 10]) < 1e-12);
        assert!(load_cv(&[40, 0, 0, 0]) > 1.0);
    }

    #[test]
    fn entropy_bounds() {
        assert!((normalized_entropy(&[5, 5, 5, 5]) - 1.0).abs() < 1e-12);
        assert!(normalized_entropy(&[20, 0, 0, 0]) < 1e-12);
        let mid = normalized_entropy(&[10, 5, 3, 2]);
        assert!(mid > 0.0 && mid < 1.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_duration(2.5), "2.500 s");
        assert_eq!(fmt_duration(0.0025), "2.500 ms");
        assert_eq!(fmt_duration(2.5e-6), "2.500 µs");
        assert_eq!(fmt_bytes(10), "10 B");
        assert_eq!(fmt_bytes(1536), "1.50 KiB");
        assert_eq!(fmt_bytes(16 * 1024 * 1024), "16.00 MiB");
    }
}
