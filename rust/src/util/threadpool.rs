//! Scoped data-parallel helpers.
//!
//! `rayon`/`tokio` are not vendored in this environment, so the
//! coordinator, the optimized layout-transform kernels and the
//! pipeline's per-expert FFN stage parallelize through these free
//! functions. Everything is built on `std::thread::scope`, so closures
//! borrow from the caller's stack with no `unsafe` and no lifetime
//! erasure, and every call returns only after all spawned work joined.
//!
//! Output splitting goes through [`parallel_rows_mut`] /
//! [`parallel_rows_mut2`]: disjoint `&mut` row chunks carved with
//! `chunks_mut`, which replaces the raw-pointer scatter the layout and
//! top-k kernels used to do. Chunk boundaries are identical to
//! [`parallel_for_chunks`] (`per = rows.div_ceil(chunks)`), so the
//! parallel kernels stay bit-identical to their serial forms.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::thread;

/// The pipeline's pool policy in one place (shared by the forward and
/// backward expert stages): run `f(i)` for `i in 0..n` on up to
/// `threads` scoped threads when `threads > 1` and there is more than
/// one job, inline otherwise. Results are ordered and identical either
/// way — each job must be an independent pure function.
pub fn pooled<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if threads > 1 && n > 1 {
        parallel_map(n, threads, f)
    } else {
        (0..n).map(f).collect()
    }
}

/// Number of logical cores (fallback 4).
pub fn available_parallelism() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Scoped data-parallel map over index chunks using `std::thread::scope`.
///
/// Splits `0..n` into `chunks` contiguous ranges and runs `f(range)` on
/// scoped threads; `f` may borrow from the caller's stack. Returns when all
/// chunks complete. Falls back to inline execution for `n == 0` or a single
/// chunk.
pub fn parallel_for_chunks<F>(n: usize, chunks: usize, f: F)
where
    F: Fn(Range<usize>) + Sync,
{
    if n == 0 {
        return;
    }
    let chunks = chunks.max(1).min(n);
    if chunks == 1 {
        f(0..n);
        return;
    }
    let per = n.div_ceil(chunks);
    thread::scope(|scope| {
        for c in 0..chunks {
            let lo = c * per;
            let hi = ((c + 1) * per).min(n);
            if lo >= hi {
                break;
            }
            let fr = &f;
            scope.spawn(move || fr(lo..hi));
        }
    });
}

/// Run `f(rows, chunk)` over disjoint row chunks of `out` (a row-major
/// `[rows, row_len]` buffer) on up to `threads` scoped threads. `rows`
/// is the chunk's global row range and `chunk` the corresponding
/// `&mut` slice, so `chunk[(r - rows.start) * row_len..]` is row `r`.
///
/// Row ranges match [`parallel_for_chunks`] exactly, so a kernel moved
/// from "parallel_for_chunks + raw pointer writes" onto this helper
/// performs the same writes in the same per-thread order.
pub fn parallel_rows_mut<T, F>(out: &mut [T], row_len: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(Range<usize>, &mut [T]) + Sync,
{
    if out.is_empty() || row_len == 0 {
        return;
    }
    debug_assert_eq!(out.len() % row_len, 0, "out must be whole rows");
    let rows = out.len() / row_len;
    let chunks = threads.max(1).min(rows);
    if chunks == 1 {
        f(0..rows, out);
        return;
    }
    let per = rows.div_ceil(chunks);
    thread::scope(|scope| {
        for (c, chunk) in out.chunks_mut(per * row_len).enumerate() {
            let lo = c * per;
            let hi = lo + chunk.len() / row_len;
            let fr = &f;
            scope.spawn(move || fr(lo..hi, chunk));
        }
    });
}

/// [`parallel_rows_mut`] over two parallel row-major buffers that share
/// a row count (`a: [rows, a_row]`, `b: [rows, b_row]`) — e.g. the
/// top-k kernels' expert-id and gate-value outputs.
pub fn parallel_rows_mut2<A, B, F>(
    a: &mut [A],
    b: &mut [B],
    a_row: usize,
    b_row: usize,
    threads: usize,
    f: F,
) where
    A: Send,
    B: Send,
    F: Fn(Range<usize>, &mut [A], &mut [B]) + Sync,
{
    if a.is_empty() || a_row == 0 || b_row == 0 {
        return;
    }
    debug_assert_eq!(a.len() % a_row, 0, "a must be whole rows");
    debug_assert_eq!(b.len() % b_row, 0, "b must be whole rows");
    let rows = a.len() / a_row;
    debug_assert_eq!(b.len() / b_row, rows, "a and b must share a row count");
    let chunks = threads.max(1).min(rows);
    if chunks == 1 {
        f(0..rows, a, b);
        return;
    }
    let per = rows.div_ceil(chunks);
    thread::scope(|scope| {
        for (c, (ca, cb)) in a
            .chunks_mut(per * a_row)
            .zip(b.chunks_mut(per * b_row))
            .enumerate()
        {
            let lo = c * per;
            let hi = lo + ca.len() / a_row;
            let fr = &f;
            scope.spawn(move || fr(lo..hi, ca, cb));
        }
    });
}

/// Scoped parallel map: applies `f(i)` for `i in 0..n` on up to `threads`
/// scoped threads, collecting results in order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots: Vec<Mutex<&mut Option<T>>> =
            out.iter_mut().map(Mutex::new).collect();
        let next = AtomicUsize::new(0);
        let nthreads = threads.max(1).min(n.max(1));
        thread::scope(|scope| {
            for _ in 0..nthreads {
                let fr = &f;
                let slots = &slots;
                let next = &next;
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        return;
                    }
                    let v = fr(i);
                    **slots[i].lock().unwrap() = Some(v);
                });
            }
        });
    }
    out.into_iter().map(|v| v.expect("slot filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn parallel_for_covers_all_indices() {
        let n = 1003;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_chunks(n, 7, |range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_handles_edges() {
        parallel_for_chunks(0, 4, |_| panic!("should not run"));
        let hit = AtomicUsize::new(0);
        parallel_for_chunks(1, 8, |r| {
            hit.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(hit.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn parallel_map_ordered() {
        let out = parallel_map(64, 8, |i| i * i);
        let expect: Vec<usize> = (0..64).map(|i| i * i).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn pooled_matches_inline() {
        // The pooled policy gives identical ordered results inline
        // (threads = 1) and parallel (threads > 1).
        let a = pooled(1, 17, |i| i + 1);
        let b = pooled(3, 17, |i| i + 1);
        assert_eq!(a, b);
    }

    #[test]
    fn rows_mut_chunks_align_with_parallel_for_chunks() {
        // Same splitting rule: a kernel migrated from raw-pointer
        // scatter must see the same row ranges.
        let rows = 23usize;
        let row_len = 3usize;
        for threads in [1, 2, 4, 7, 23, 64] {
            // Reference ranges: the parallel_for_chunks splitting rule.
            let mut expect: Vec<Vec<usize>> = Vec::new();
            let chunks = threads.max(1).min(rows);
            let per = rows.div_ceil(chunks);
            for c in 0..chunks {
                let lo = c * per;
                let hi = ((c + 1) * per).min(rows);
                if lo < hi {
                    expect.push((lo..hi).collect());
                }
            }
            let mut out = vec![0usize; rows * row_len];
            let seen = Mutex::new(Vec::new());
            parallel_rows_mut(&mut out, row_len, threads, |r, chunk| {
                assert_eq!(chunk.len(), r.len() * row_len);
                for (off, row) in r.clone().enumerate() {
                    for x in &mut chunk[off * row_len..(off + 1) * row_len] {
                        *x = row;
                    }
                }
                seen.lock().unwrap().push(r.collect::<Vec<_>>());
            });
            let mut seen = seen.into_inner().unwrap();
            seen.sort_by_key(|v| v[0]);
            assert_eq!(seen, expect, "threads={threads}");
            for row in 0..rows {
                assert!(out[row * row_len..(row + 1) * row_len].iter().all(|&x| x == row));
            }
        }
    }

    #[test]
    fn rows_mut2_writes_both_buffers() {
        let rows = 11usize;
        let (ar, br) = (2usize, 5usize);
        let mut a = vec![0u32; rows * ar];
        let mut b = vec![0.0f32; rows * br];
        parallel_rows_mut2(&mut a, &mut b, ar, br, 3, |r, ca, cb| {
            for (off, row) in r.enumerate() {
                ca[off * ar..(off + 1) * ar].fill(row as u32);
                cb[off * br..(off + 1) * br].fill(row as f32);
            }
        });
        for row in 0..rows {
            assert!(a[row * ar..(row + 1) * ar].iter().all(|&x| x == row as u32));
            assert!(b[row * br..(row + 1) * br].iter().all(|&x| x == row as f32));
        }
    }

    #[test]
    fn rows_mut_handles_edges() {
        let mut empty: Vec<u32> = Vec::new();
        parallel_rows_mut(&mut empty, 4, 8, |_, _| panic!("should not run"));
        let mut one = vec![0u8; 5];
        parallel_rows_mut(&mut one, 5, 8, |r, chunk| {
            assert_eq!(r, 0..1);
            chunk.fill(7);
        });
        assert!(one.iter().all(|&x| x == 7));
    }
}
