//! A small scoped thread pool.
//!
//! `rayon`/`tokio` are not vendored in this environment, so the coordinator,
//! the optimized layout-transform kernels and the pipeline's per-expert FFN
//! stage use this pool: fixed worker threads, a shared FIFO injector queue,
//! and a scoped [`ThreadPool::parallel_for`] that borrows from the caller's
//! stack (the call blocks on a completion latch, so the borrow outlives
//! every job).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
    shutdown: Mutex<bool>,
}

/// Fixed-size thread pool with FIFO job execution (submission order).
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Create a pool with `size` worker threads (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: Mutex::new(false),
        });
        let mut workers = Vec::with_capacity(size);
        for i in 0..size {
            let sh = Arc::clone(&shared);
            workers.push(
                thread::Builder::new()
                    .name(format!("hetu-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker"),
            );
        }
        ThreadPool { shared, workers, size }
    }

    /// Pool with one worker per available core.
    pub fn with_cores() -> Self {
        ThreadPool::new(available_parallelism())
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a job (fire and forget). Jobs run in submission order
    /// (FIFO) — chunked pipeline stages rely on early-submitted chunk
    /// jobs not being starved by later ones.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let mut q = self.shared.queue.lock().unwrap();
        q.push_back(Box::new(f));
        drop(q);
        self.shared.cv.notify_one();
    }

    /// Scoped data-parallel for: runs `f(i)` for every `i in 0..n` on
    /// the pool's workers and returns once all indices completed. `f`
    /// may borrow from the caller's stack — the call blocks on a
    /// completion latch, so the borrow outlives every job. Indices are
    /// claimed atomically, so work stays balanced under uneven job
    /// sizes. Must not be called from inside a pool job (a waiting
    /// inner call could deadlock a fully busy pool).
    pub fn parallel_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        self.parallel_for_capped(self.size, n, f)
    }

    /// [`Self::parallel_for`] with at most `cap` jobs in flight, so a
    /// caller-facing thread budget (e.g. `MoeLayerOptions::threads`)
    /// bounds concurrency even on the shared all-cores pool.
    pub fn parallel_for_capped<F>(&self, cap: usize, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if n == 0 {
            return;
        }
        let workers = cap.min(self.size).min(n);
        if workers <= 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        struct Latch {
            done: Mutex<usize>,
            cv: Condvar,
        }
        let latch = Arc::new(Latch { done: Mutex::new(0), cv: Condvar::new() });
        let next = Arc::new(AtomicUsize::new(0));
        let poisoned = Arc::new(AtomicBool::new(false));
        // SAFETY: the lifetime-erased reference lets the 'static job
        // closures reach the stack-borrowed `f`; `parallel_for` blocks
        // until every job has signalled the latch, so `f` outlives every
        // call through it. (`&dyn` rather than `*const F` so the job
        // closure's type does not mention `F` and `f` needn't be
        // 'static itself.)
        let f_ref: &(dyn Fn(usize) + Sync) = &f;
        let f_static: &'static (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute(f_ref) };
        for _ in 0..workers {
            let latch = Arc::clone(&latch);
            let next = Arc::clone(&next);
            let poisoned = Arc::clone(&poisoned);
            self.execute(move || {
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || f_static(i),
                    ))
                    .is_ok();
                    if !ok {
                        poisoned.store(true, Ordering::SeqCst);
                        break;
                    }
                }
                let mut done = latch.done.lock().unwrap();
                *done += 1;
                latch.cv.notify_all();
            });
        }
        let mut done = latch.done.lock().unwrap();
        while *done < workers {
            done = latch.cv.wait(done).unwrap();
        }
        drop(done);
        if poisoned.load(Ordering::SeqCst) {
            panic!("ThreadPool::parallel_for: a job panicked");
        }
    }

    /// Ordered parallel map on the pool: `out[i] = f(i)` for `i in
    /// 0..n`, with the same scoped-borrow contract as
    /// [`Self::parallel_for`].
    pub fn parallel_map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        self.parallel_map_capped(self.size, n, f)
    }

    /// [`Self::parallel_map`] with at most `cap` jobs in flight.
    pub fn parallel_map_capped<T, F>(&self, cap: usize, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
        self.parallel_for_capped(cap, n, |i| {
            *slots[i].lock().unwrap() = Some(f(i));
        });
        slots
            .into_iter()
            .map(|s| s.into_inner().unwrap().expect("slot filled"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break Some(j);
                }
                if *shared.shutdown.lock().unwrap() {
                    break None;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        match job {
            Some(j) => j(),
            None => return,
        }
    }
}

/// Process-wide shared pool (one worker per core), created on first
/// use. The unified step pipeline runs its per-expert FFN batches here
/// so chunked expert compute does not pay pool construction per step.
pub fn global() -> &'static ThreadPool {
    static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();
    GLOBAL.get_or_init(ThreadPool::with_cores)
}

/// The pipeline's pool policy in one place (shared by the forward and
/// backward expert stages): run `f(i)` for `i in 0..n` on the global
/// pool when `threads > 1` and there is more than one job — capped at
/// `threads` jobs in flight, so the caller's thread budget is honored
/// even though the shared pool has one worker per core — inline
/// otherwise. Results are ordered and identical either way — each job
/// must be an independent pure function.
pub fn pooled<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if threads > 1 && n > 1 {
        global().parallel_map_capped(threads, n, f)
    } else {
        (0..n).map(f).collect()
    }
}

/// Number of logical cores (fallback 4).
pub fn available_parallelism() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Scoped data-parallel map over index chunks using `std::thread::scope`.
///
/// Splits `0..n` into `chunks` contiguous ranges and runs `f(range)` on
/// scoped threads; `f` may borrow from the caller's stack. Returns when all
/// chunks complete. Falls back to inline execution for `n == 0` or a single
/// chunk.
pub fn parallel_for_chunks<F>(n: usize, chunks: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    if n == 0 {
        return;
    }
    let chunks = chunks.max(1).min(n);
    if chunks == 1 {
        f(0..n);
        return;
    }
    let per = n.div_ceil(chunks);
    thread::scope(|scope| {
        for c in 0..chunks {
            let lo = c * per;
            let hi = ((c + 1) * per).min(n);
            if lo >= hi {
                break;
            }
            let fr = &f;
            scope.spawn(move || fr(lo..hi));
        }
    });
}

/// Scoped parallel map: applies `f(i)` for `i in 0..n` on up to `threads`
/// scoped threads, collecting results in order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots: Vec<Mutex<&mut Option<T>>> =
            out.iter_mut().map(Mutex::new).collect();
        let next = AtomicUsize::new(0);
        let nthreads = threads.max(1).min(n.max(1));
        thread::scope(|scope| {
            for _ in 0..nthreads {
                let fr = &f;
                let slots = &slots;
                let next = &next;
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        return;
                    }
                    let v = fr(i);
                    **slots[i].lock().unwrap() = Some(v);
                });
            }
        });
    }
    out.into_iter().map(|v| v.expect("slot filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let latch = Arc::new((Mutex::new(0usize), Condvar::new()));
        let n = 100;
        for _ in 0..n {
            let c = Arc::clone(&counter);
            let l = Arc::clone(&latch);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let (m, cv) = &*l;
                *m.lock().unwrap() += 1;
                cv.notify_all();
            });
        }
        let (m, cv) = &*latch;
        let mut done = m.lock().unwrap();
        while *done < n {
            done = cv.wait(done).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), n);
    }

    #[test]
    fn jobs_run_in_submission_order() {
        // One worker: execution order must equal submission order — the
        // queue is FIFO, not a LIFO stack that starves early jobs.
        let pool = ThreadPool::new(1);
        let order = Arc::new(Mutex::new(Vec::new()));
        let latch = Arc::new((Mutex::new(0usize), Condvar::new()));
        let n = 64usize;
        for i in 0..n {
            let order = Arc::clone(&order);
            let latch = Arc::clone(&latch);
            pool.execute(move || {
                order.lock().unwrap().push(i);
                let (m, cv) = &*latch;
                *m.lock().unwrap() += 1;
                cv.notify_all();
            });
        }
        let (m, cv) = &*latch;
        let mut done = m.lock().unwrap();
        while *done < n {
            done = cv.wait(done).unwrap();
        }
        drop(done);
        let got = order.lock().unwrap().clone();
        let expect: Vec<usize> = (0..n).collect();
        assert_eq!(got, expect, "FIFO queue must preserve submission order");
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }

    #[test]
    fn pool_parallel_for_covers_all_indices() {
        let pool = ThreadPool::new(4);
        let n = 257usize;
        // Borrows from the caller's stack — the scoped contract.
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_for(n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        // Edge cases: empty and single-index runs execute inline.
        pool.parallel_for(0, |_| unreachable!("no indices"));
        let one = AtomicUsize::new(0);
        pool.parallel_for(1, |_| {
            one.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(one.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn capped_parallel_map_covers_all_indices() {
        let pool = ThreadPool::new(4);
        let out = pool.parallel_map_capped(2, 33, |i| i * 3);
        let expect: Vec<usize> = (0..33).map(|i| i * 3).collect();
        assert_eq!(out, expect);
        // The pooled policy gives identical ordered results inline
        // (threads = 1) and pooled (threads > 1).
        let a = pooled(1, 17, |i| i + 1);
        let b = pooled(3, 17, |i| i + 1);
        assert_eq!(a, b);
    }

    #[test]
    fn global_pool_is_shared_and_usable() {
        let a = global() as *const ThreadPool;
        let b = global() as *const ThreadPool;
        assert_eq!(a, b);
        let n = 32usize;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        global().parallel_for(n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_covers_all_indices() {
        let n = 1003;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_chunks(n, 7, |range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_handles_edges() {
        parallel_for_chunks(0, 4, |_| panic!("should not run"));
        let hit = AtomicUsize::new(0);
        parallel_for_chunks(1, 8, |r| {
            hit.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(hit.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn parallel_map_ordered() {
        let out = parallel_map(64, 8, |i| i * i);
        let expect: Vec<usize> = (0..64).map(|i| i * i).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }
}
