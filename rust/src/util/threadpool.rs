//! A small scoped thread pool.
//!
//! `rayon`/`tokio` are not vendored in this environment, so the coordinator
//! and the optimized layout-transform kernels use this pool: fixed worker
//! threads, a shared injector queue, and a scoped `parallel_for` that
//! borrows from the caller's stack (via `std::thread::scope` semantics
//! implemented with raw scope-bound closures and a completion latch).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<Vec<Job>>,
    cv: Condvar,
    shutdown: Mutex<bool>,
}

/// Fixed-size thread pool with FIFO-ish job execution.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Create a pool with `size` worker threads (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(Vec::new()),
            cv: Condvar::new(),
            shutdown: Mutex::new(false),
        });
        let mut workers = Vec::with_capacity(size);
        for i in 0..size {
            let sh = Arc::clone(&shared);
            workers.push(
                thread::Builder::new()
                    .name(format!("hetu-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker"),
            );
        }
        ThreadPool { shared, workers, size }
    }

    /// Pool with one worker per available core.
    pub fn with_cores() -> Self {
        ThreadPool::new(available_parallelism())
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a job (fire and forget).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let mut q = self.shared.queue.lock().unwrap();
        q.push(Box::new(f));
        drop(q);
        self.shared.cv.notify_one();
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop() {
                    break Some(j);
                }
                if *shared.shutdown.lock().unwrap() {
                    break None;
                }
                q = shared.cv.wait(q).unwrap();
            }
        };
        match job {
            Some(j) => j(),
            None => return,
        }
    }
}

/// Number of logical cores (fallback 4).
pub fn available_parallelism() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Scoped data-parallel map over index chunks using `std::thread::scope`.
///
/// Splits `0..n` into `chunks` contiguous ranges and runs `f(range)` on
/// scoped threads; `f` may borrow from the caller's stack. Returns when all
/// chunks complete. Falls back to inline execution for `n == 0` or a single
/// chunk.
pub fn parallel_for_chunks<F>(n: usize, chunks: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    if n == 0 {
        return;
    }
    let chunks = chunks.max(1).min(n);
    if chunks == 1 {
        f(0..n);
        return;
    }
    let per = n.div_ceil(chunks);
    thread::scope(|scope| {
        for c in 0..chunks {
            let lo = c * per;
            let hi = ((c + 1) * per).min(n);
            if lo >= hi {
                break;
            }
            let fr = &f;
            scope.spawn(move || fr(lo..hi));
        }
    });
}

/// Scoped parallel map: applies `f(i)` for `i in 0..n` on up to `threads`
/// scoped threads, collecting results in order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots: Vec<Mutex<&mut Option<T>>> =
            out.iter_mut().map(Mutex::new).collect();
        let next = AtomicUsize::new(0);
        let nthreads = threads.max(1).min(n.max(1));
        thread::scope(|scope| {
            for _ in 0..nthreads {
                let fr = &f;
                let slots = &slots;
                let next = &next;
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        return;
                    }
                    let v = fr(i);
                    **slots[i].lock().unwrap() = Some(v);
                });
            }
        });
    }
    out.into_iter().map(|v| v.expect("slot filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let latch = Arc::new((Mutex::new(0usize), Condvar::new()));
        let n = 100;
        for _ in 0..n {
            let c = Arc::clone(&counter);
            let l = Arc::clone(&latch);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let (m, cv) = &*l;
                *m.lock().unwrap() += 1;
                cv.notify_all();
            });
        }
        let (m, cv) = &*latch;
        let mut done = m.lock().unwrap();
        while *done < n {
            done = cv.wait(done).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), n);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang
    }

    #[test]
    fn parallel_for_covers_all_indices() {
        let n = 1003;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_chunks(n, 7, |range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_handles_edges() {
        parallel_for_chunks(0, 4, |_| panic!("should not run"));
        let hit = AtomicUsize::new(0);
        parallel_for_chunks(1, 8, |r| {
            hit.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(hit.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn parallel_map_ordered() {
        let out = parallel_map(64, 8, |i| i * i);
        let expect: Vec<usize> = (0..64).map(|i| i * i).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }
}
