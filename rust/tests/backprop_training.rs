//! End-to-end native-training integration tests: the seeded loss-curve
//! guarantee, the padded-vs-ragged backward equivalence, and the
//! trainer-level consequence of that equivalence (identical training
//! trajectories in both dispatch modes).

use hetumoe::backprop::{smoothed_losses, NativeTrainer, TrainMoeLayer, TrainRunConfig};
use hetumoe::config::{ClusterConfig, GateKind, MoeConfig};
use hetumoe::moe::{DispatchMode, MoeLayerOptions};
use hetumoe::tensor::Tensor;
use hetumoe::util::proptest::for_all;
use hetumoe::util::rng::Rng;

fn small_cluster() -> ClusterConfig {
    ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) }
}

/// The acceptance-criteria run: a seeded synthetic task whose labels
/// correlate with token clusters must show monotonically decreasing
/// smoothed loss over 200+ steps, with expert balance not degrading.
#[test]
fn seeded_loss_curve_decreases_over_200_steps() {
    let cfg = TrainRunConfig {
        moe: MoeConfig {
            num_experts: 4,
            d_model: 16,
            ffn_hidden: 32,
            capacity_factor: 2.0,
            gate: GateKind::Switch,
        },
        cluster: small_cluster(),
        opts: MoeLayerOptions::default(),
        steps: 220,
        tokens_per_rank: 32,
        num_classes: 4,
        lr: 3e-3,
        aux_coef: 1e-2,
        noise: 0.3,
        seed: 0,
        log_every: 0,
        faults: hetumoe::fault::FaultPlan::none(),
        ckpt_every: 0,
        ckpt_dir: None,
        ..TrainRunConfig::default_run()
    };
    let mut t = NativeTrainer::new(cfg).unwrap();
    let summary = t.run().unwrap();
    assert_eq!(summary.steps, 220);
    let losses = t.losses();
    let smooth = smoothed_losses(&losses, 0.1);
    // Smoothed loss strictly decreases across checkpoints.
    let checkpoints = [20usize, 70, 120, 170, 219];
    for w in checkpoints.windows(2) {
        assert!(
            smooth[w[1]] < smooth[w[0]],
            "smoothed loss must strictly decrease: step {} = {:.4} vs step {} = {:.4}",
            w[0],
            smooth[w[0]],
            w[1],
            smooth[w[1]]
        );
    }
    assert!(
        smooth[219] < 0.7 * smooth[20],
        "improvement must be substantial: {:.4} → {:.4}",
        smooth[20],
        smooth[219]
    );
    // Expert balance must not degrade while the loss falls (the aux
    // term actively pushes toward balance).
    let cv_first: f64 = t.logs[..50].iter().map(|l| l.load_cv).sum::<f64>() / 50.0;
    let cv_last: f64 = t.logs[170..].iter().map(|l| l.load_cv).sum::<f64>() / 50.0;
    assert!(
        cv_last <= cv_first + 0.10,
        "expert balance must not degrade: load CV {cv_first:.3} → {cv_last:.3}"
    );
    // Backward attribution present on every step.
    for log in &t.logs {
        assert!(log.report.bytes_on_wire_bwd > 0);
        assert!(!log.report.comm_schedule_bwd.is_empty());
    }
}

/// Ragged and padded backward produce bit-identical gradients across
/// gates, capacity regimes (including heavy drops) and batch shapes.
#[test]
fn backward_grads_bitwise_equal_across_modes_property() {
    for_all(10, |g| {
        let gates = [GateKind::Switch, GateKind::TopK { k: 2 }, GateKind::GShard];
        let gate = g.choose(&gates).clone();
        let cf = *g.choose(&[0.5f64, 1.0, 2.0, 4.0]);
        let cfg = MoeConfig {
            num_experts: 4,
            d_model: 8,
            ffn_hidden: 16,
            capacity_factor: cf,
            gate,
        };
        let tokens = g.usize_in(4..24);
        let seed = g.case as u64;
        let mk = |dispatch| {
            TrainMoeLayer::native(
                cfg.clone(),
                small_cluster(),
                MoeLayerOptions { dispatch, ..Default::default() },
                seed,
            )
            .unwrap()
        };
        let ragged = mk(DispatchMode::Ragged);
        let padded = mk(DispatchMode::Padded);
        let mut rng = Rng::seed(seed ^ 0x5EED);
        let shards: Vec<Tensor> =
            (0..4).map(|_| Tensor::randn(&[tokens, 8], &mut rng)).collect();
        let dy: Vec<Tensor> = (0..4).map(|_| Tensor::randn(&[tokens, 8], &mut rng)).collect();
        let (ro, _, rc) = ragged.forward_t(&shards, 0).unwrap();
        let (po, _, pc) = padded.forward_t(&shards, 0).unwrap();
        for (a, b) in ro.iter().zip(&po) {
            assert!(a.allclose(b, 0.0), "forward outputs must be bit-identical");
        }
        let (rdx, rg, _) = ragged.backward(&shards, &dy, &rc, 0.01).unwrap();
        let (pdx, pg, _) = padded.backward(&shards, &dy, &pc, 0.01).unwrap();
        for (a, b) in rdx.iter().zip(&pdx) {
            assert!(a.allclose(b, 0.0), "dx must be bit-identical (cf={cf})");
        }
        for (a, b) in rg.d_gate_weight.iter().zip(&pg.d_gate_weight) {
            assert!(a.allclose(b, 0.0), "d_gate_weight must be bit-identical (cf={cf})");
        }
        for (a, b) in rg.experts.iter().zip(&pg.experts) {
            assert!(a.dw1.allclose(&b.dw1, 0.0), "dw1 (cf={cf})");
            assert!(a.dw2.allclose(&b.dw2, 0.0), "dw2 (cf={cf})");
            for (x, y) in a.db1.iter().zip(&b.db1) {
                assert!((x - y).abs() == 0.0, "db1 (cf={cf})");
            }
            for (x, y) in a.db2.iter().zip(&b.db2) {
                assert!((x - y).abs() == 0.0, "db2 (cf={cf})");
            }
        }
    });
}

/// The trainer-level consequence: with bit-identical gradients, whole
/// training trajectories coincide exactly between dispatch modes.
#[test]
fn training_trajectories_identical_across_dispatch_modes() {
    let base = TrainRunConfig {
        moe: MoeConfig {
            num_experts: 4,
            d_model: 16,
            ffn_hidden: 32,
            // Generous capacity: padded buffers carry real padding, so
            // the strict bytes-on-wire comparison below always holds.
            capacity_factor: 2.0,
            gate: GateKind::Switch,
        },
        cluster: small_cluster(),
        opts: MoeLayerOptions::default(),
        steps: 10,
        tokens_per_rank: 16,
        num_classes: 4,
        lr: 5e-3,
        aux_coef: 1e-2,
        noise: 0.3,
        seed: 7,
        log_every: 0,
        faults: hetumoe::fault::FaultPlan::none(),
        ckpt_every: 0,
        ckpt_dir: None,
        ..TrainRunConfig::default_run()
    };
    let mut ragged = NativeTrainer::new(TrainRunConfig {
        opts: MoeLayerOptions { dispatch: DispatchMode::Ragged, ..Default::default() },
        ..base.clone()
    })
    .unwrap();
    let mut padded = NativeTrainer::new(TrainRunConfig {
        opts: MoeLayerOptions { dispatch: DispatchMode::Padded, ..Default::default() },
        ..base
    })
    .unwrap();
    for _ in 0..10 {
        let lr = ragged.step().unwrap();
        let lp = padded.step().unwrap();
        assert_eq!(lr.loss, lp.loss, "step {}: losses must be bitwise equal", lr.step);
        assert_eq!(lr.report.expert_counts, lp.report.expert_counts);
    }
    // But the padded mode pays for it: more bytes on the wire in both
    // directions whenever there is padding.
    let lr = ragged.logs.last().unwrap();
    let lp = padded.logs.last().unwrap();
    assert!(lr.report.bytes_on_wire < lp.report.bytes_on_wire);
    assert!(lr.report.bytes_on_wire_bwd < lp.report.bytes_on_wire_bwd);
}
