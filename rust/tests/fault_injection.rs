//! Fault-injection integration tests: the no-fault bit-identity
//! guarantee, timing-only straggler semantics, checkpoint round-trip
//! exactness, rank-failure recovery equalling a fresh restart from the
//! same checkpoint with the shrunken world, serving resilience under
//! dead ranks, and a deterministic chaos sweep over both paths.

use hetumoe::backprop::{NativeTrainer, TrainRunConfig};
use hetumoe::config::{ClusterConfig, GateKind, MoeConfig};
use hetumoe::fault::FaultPlan;
use hetumoe::moe::MoeLayerOptions;
use hetumoe::serve::{ArrivalProcess, ServeConfig, ServeEngine};
use std::path::PathBuf;

fn train_cfg() -> TrainRunConfig {
    TrainRunConfig {
        moe: MoeConfig {
            num_experts: 4,
            d_model: 16,
            ffn_hidden: 32,
            capacity_factor: 2.0,
            gate: GateKind::Switch,
        },
        cluster: ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) },
        opts: MoeLayerOptions::default(),
        steps: 10,
        tokens_per_rank: 16,
        num_classes: 4,
        lr: 5e-3,
        aux_coef: 1e-2,
        noise: 0.3,
        seed: 0,
        log_every: 0,
        faults: FaultPlan::none(),
        ckpt_every: 0,
        ckpt_dir: None,
        ..TrainRunConfig::default_run()
    }
}

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        cluster: ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) },
        moe: MoeConfig {
            num_experts: 8,
            d_model: 16,
            ffn_hidden: 32,
            capacity_factor: 1.5,
            gate: GateKind::Switch,
        },
        process: ArrivalProcess::Poisson { rate: 500.0 },
        duration: 0.3,
        ..ServeConfig::default_run()
    }
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// The headline invariant: a plan whose targets all fall outside the
/// world injects nothing, and the run is bit-identical to a run with no
/// plan at all — every loss, expert count, and timing figure.
#[test]
fn inert_plan_is_bit_identical_to_no_faults() {
    let mut clean = NativeTrainer::new(train_cfg()).unwrap();
    let mut inert = NativeTrainer::new(TrainRunConfig {
        faults: FaultPlan::parse("straggle:rank=99,x=3; nic:node=99,x=2").unwrap(),
        ..train_cfg()
    })
    .unwrap();
    clean.run().unwrap();
    inert.run().unwrap();
    for (a, b) in clean.logs.iter().zip(&inert.logs) {
        assert_eq!(a.loss, b.loss, "step {}: loss drifted", a.step);
        assert_eq!(a.report.expert_counts, b.report.expert_counts);
        assert_eq!(a.report.critical_path, b.report.critical_path);
        assert_eq!(a.report.faults_injected, 0);
        assert_eq!(b.report.faults_injected, 0);
    }
}

/// Stragglers, NIC degradation and retries are purely additive on the
/// simulated clock: the learning trajectory never moves.
#[test]
fn faults_change_timing_but_not_the_trajectory() {
    let mut clean = NativeTrainer::new(train_cfg()).unwrap();
    let mut slow = NativeTrainer::new(TrainRunConfig {
        faults: FaultPlan::parse(
            "straggle:rank=1,x=3; nic:node=0,x=2,from=2,until=6; flaky:rank=0,step=3,n=2",
        )
        .unwrap(),
        ..train_cfg()
    })
    .unwrap();
    clean.run().unwrap();
    slow.run().unwrap();
    let mut injected_total = 0.0;
    let mut retries = 0;
    for (a, b) in clean.logs.iter().zip(&slow.logs) {
        assert_eq!(a.loss, b.loss, "step {}: faults must not move the loss", a.step);
        assert_eq!(a.report.expert_counts, b.report.expert_counts);
        assert!(
            b.report.critical_path >= a.report.critical_path,
            "injected delay can only lengthen the critical path"
        );
        injected_total += b.report.injected_delay;
        retries += b.report.retries;
    }
    assert!(injected_total > 0.0, "the plan must actually inject delay");
    assert_eq!(retries, 2, "flaky:n=2 charges exactly two retries");
    assert!(slow.fault_timeline.total() > 0.0);
}

/// Save at step N, restore, run to the end: the resumed trajectory is
/// bit-identical to the uninterrupted one — parameters, Adam moments,
/// and the data-RNG stream all round-trip exactly.
#[test]
fn checkpoint_restore_resumes_bit_identically() {
    let dir = tmp("hetu_fault_ckpt_rt");
    let cfg = TrainRunConfig {
        steps: 12,
        ckpt_every: 6,
        ckpt_dir: Some(dir.to_string_lossy().into_owned()),
        ..train_cfg()
    };
    let mut straight = NativeTrainer::new(cfg.clone()).unwrap();
    straight.run().unwrap();
    let ckpt = dir.join("ckpt_000006.bin");
    assert!(ckpt.is_file(), "run must have checkpointed at step 6");
    let mut resumed = NativeTrainer::from_checkpoint(cfg, &ckpt).unwrap();
    resumed.run().unwrap();
    assert_eq!(resumed.logs.len(), 6, "resume covers exactly steps 6..12");
    for log in &resumed.logs {
        let orig = &straight.logs[log.step];
        assert_eq!(orig.loss, log.loss, "step {}: resumed loss drifted", log.step);
        assert_eq!(orig.report.expert_counts, log.report.expert_counts);
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// A `kill:` fires mid-run: recovery restores the last checkpoint with
/// the victim marked dead, and the post-recovery trajectory exactly
/// matches a fresh trainer started from that same checkpoint with the
/// shrunken world.
#[test]
fn kill_recovery_equals_fresh_restart_from_checkpoint() {
    let dir = tmp("hetu_fault_kill_rec");
    let cfg = TrainRunConfig {
        steps: 10,
        ckpt_every: 2,
        ckpt_dir: Some(dir.to_string_lossy().into_owned()),
        faults: FaultPlan::parse("kill:rank=3,step=5").unwrap(),
        ..train_cfg()
    };
    let mut killed = NativeTrainer::new(cfg).unwrap();
    let summary = killed.run().unwrap();
    assert_eq!(summary.steps, 10);
    // Last checkpoint before the kill was step 4: one step re-executed.
    assert_eq!(summary.recovery_steps, 1);
    assert_eq!(killed.layer.opts.dead_ranks, vec![3]);

    // Fresh trainer from the same pre-kill checkpoint + dead rank 3.
    let mut fresh_cfg = TrainRunConfig { steps: 10, ..train_cfg() };
    fresh_cfg.opts.dead_ranks = vec![3];
    let mut fresh =
        NativeTrainer::from_checkpoint(fresh_cfg, &dir.join("ckpt_000004.bin")).unwrap();
    fresh.run().unwrap();
    let killed_tail: Vec<_> = killed.logs.iter().filter(|l| l.step >= 4).collect();
    assert_eq!(killed_tail.len(), fresh.logs.len());
    for (a, b) in killed_tail.iter().zip(&fresh.logs) {
        assert_eq!(a.step, b.step);
        assert_eq!(a.loss, b.loss, "step {}: recovery trajectory diverged", a.step);
        assert_eq!(a.report.expert_counts, b.report.expert_counts);
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Without a checkpoint there is nothing to recover from — the run
/// fails with a typed, actionable error instead of a panic.
#[test]
fn kill_without_checkpoint_is_a_typed_error() {
    let mut t = NativeTrainer::new(TrainRunConfig {
        faults: FaultPlan::parse("kill:rank=1,step=2").unwrap(),
        ..train_cfg()
    })
    .unwrap();
    let err = t.run().unwrap_err();
    assert!(matches!(err, hetumoe::error::HetuError::Fault(_)));
    assert!(err.to_string().contains("--ckpt-every"), "error must name the fix: {err}");
}

/// `dead:` ranks are down from step 0: the elastic placement remaps
/// their experts onto survivors and training still converges.
#[test]
fn training_with_an_initially_dead_rank_still_learns() {
    let mut t = NativeTrainer::new(TrainRunConfig {
        steps: 30,
        faults: FaultPlan::parse("dead:rank=3").unwrap(),
        ..train_cfg()
    })
    .unwrap();
    let summary = t.run().unwrap();
    assert!(summary.final_loss.is_finite());
    let losses = t.losses();
    let first5: f32 = losses[..5].iter().sum::<f32>() / 5.0;
    let last5: f32 = losses[25..].iter().sum::<f32>() / 5.0;
    assert!(last5 < first5, "degraded world must still learn: {first5} → {last5}");
    // Rank 3's experts were remapped: every step's counts cover all 4.
    assert_eq!(t.logs[0].report.expert_counts.len(), 4);
}

/// Serving routes around a dead node: goodput stays positive and the
/// tail latency stays finite.
#[test]
fn serving_survives_dead_and_killed_ranks() {
    // Dead from the start.
    let mut engine = ServeEngine::new(ServeConfig {
        dead_ranks: vec![1],
        ..serve_cfg()
    })
    .unwrap();
    let report = engine.run().unwrap();
    assert!(report.completed > 0, "a dead rank must not stop service");
    assert!(report.goodput_rps > 0.0);
    assert!(report.latency.p99.is_finite());

    // Killed mid-run (batch 3), plus a straggler: still serving.
    let mut chaos = ServeEngine::new(ServeConfig {
        faults: FaultPlan::parse("kill:rank=2,step=3; straggle:rank=0,x=2").unwrap(),
        ..serve_cfg()
    })
    .unwrap();
    let r = chaos.run().unwrap();
    assert!(r.completed > 0);
    assert!(r.goodput_rps > 0.0);
    assert!(r.latency.p99.is_finite());
    assert!(r.faults_injected > 0, "the kill and stragglers must be counted");
}

/// An inert plan leaves the serving report bit-identical too.
#[test]
fn serving_inert_plan_matches_no_faults() {
    let mut clean = ServeEngine::new(serve_cfg()).unwrap();
    let a = clean.run().unwrap();
    let mut inert = ServeEngine::new(ServeConfig {
        faults: FaultPlan::parse("straggle:rank=99,x=4").unwrap(),
        ..serve_cfg()
    })
    .unwrap();
    let b = inert.run().unwrap();
    assert_eq!(a.offered, b.offered);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.latency.p50, b.latency.p50);
    assert_eq!(b.faults_injected, 0);
}

/// Deterministic chaos sweep over both paths: every seeded run finishes
/// with finite numbers, never panics, and replays identically.
#[test]
fn chaos_sweep_is_finite_and_deterministic() {
    // Chaos injects probabilistically per step; over 3 seeds × 8 train
    // steps plus the serve batches, at least one fault must land.
    let mut injected_total = 0usize;
    for seed in 1..=3u64 {
        let spec = format!("chaos:seed={seed}");
        let cfg = TrainRunConfig {
            steps: 8,
            faults: FaultPlan::parse(&spec).unwrap(),
            ..train_cfg()
        };
        let mut a = NativeTrainer::new(cfg.clone()).unwrap();
        let mut b = NativeTrainer::new(cfg).unwrap();
        let sa = a.run().unwrap();
        let sb = b.run().unwrap();
        assert!(sa.final_loss.is_finite());
        assert_eq!(sa.final_loss, sb.final_loss, "chaos must replay bit-identically");
        assert_eq!(sa.breakdown.faults_injected, sb.breakdown.faults_injected);
        injected_total += sa.breakdown.faults_injected;

        let scfg = ServeConfig { faults: FaultPlan::parse(&spec).unwrap(), ..serve_cfg() };
        let mut s1 = ServeEngine::new(scfg.clone()).unwrap();
        let mut s2 = ServeEngine::new(scfg).unwrap();
        let r1 = s1.run().unwrap();
        let r2 = s2.run().unwrap();
        assert!(r1.completed > 0);
        assert!(r1.latency.p99.is_finite());
        assert_eq!(r1.completed, r2.completed);
        assert_eq!(r1.faults_injected, r2.faults_injected);
        injected_total += r1.faults_injected;
    }
    assert!(injected_total > 0, "chaos injected nothing across the whole sweep");
}
