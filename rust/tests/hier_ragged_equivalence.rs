//! Property tests for the real four-phase ragged hierarchical
//! AllToAllv: with `Schedule::Hierarchical` the pipeline now *executes*
//! gather → leader aggregation/dedup → exact-count inter-node exchange
//! → expansion/scatter, and everything it produces must be bit-identical
//! to the flat ragged exchange — outputs, gradients, expert counts and
//! drop rates — across (nodes, gpus_per_node) grids, every gate family
//! including k ≥ 2, chunked and unchunked execution, drop and no-drop
//! regimes, dedup on and off.

use hetumoe::backprop::TrainMoeLayer;
use hetumoe::comm::schedule::CommChoice;
use hetumoe::config::{ClusterConfig, GateKind, MoeConfig};
use hetumoe::moe::{MoeLayer, MoeLayerOptions};
use hetumoe::pipeline::ChunkChoice;
use hetumoe::tensor::Tensor;
use hetumoe::util::proptest::for_all;
use hetumoe::util::rng::Rng;

fn cluster(nodes: usize, gpus: usize) -> ClusterConfig {
    ClusterConfig { nodes, gpus_per_node: gpus, ..ClusterConfig::commodity(nodes) }
}

/// A gate family valid for `e` experts (gshard needs ≥ 2, top-k needs
/// k ≤ E), covering k ∈ {1, 2, 4} as `e` allows.
fn gate_for(i: usize, e: usize) -> GateKind {
    match i % 4 {
        1 if e >= 2 => GateKind::GShard,        // k = 2
        2 if e >= 2 => GateKind::TopK { k: 2 }, // k = 2
        3 if e >= 4 => GateKind::TopK { k: 4 }, // k = 4
        _ => GateKind::Switch,                  // k = 1
    }
}

/// Forward path: forced-hierarchical (dedup on and off, chunked and
/// unchunked) must be bit-identical to forced-flat on every output and
/// routing statistic, across topology/gate/capacity grids.
#[test]
fn hier_ragged_forward_is_bit_identical_to_flat() {
    for_all(16, |g| {
        let nodes = g.usize_in(1..4);
        let gpus = g.usize_in(1..4);
        let w = nodes * gpus;
        let epr = g.usize_in(1..3);
        let e = w * epr;
        let d = 4 * g.usize_in(1..3);
        let tokens = g.usize_in(4..24);
        let gate = gate_for(g.usize_in(0..4), e);
        let cfg = MoeConfig {
            num_experts: e,
            d_model: d,
            ffn_hidden: 2 * d,
            // Drop and no-drop regimes.
            capacity_factor: g.f32_in(0.4, 3.0) as f64,
            gate: gate.clone(),
        };
        let cl = cluster(nodes, gpus);
        let seed = g.case as u64 + 1013;
        let mk = |alltoall, dedup, chunks| {
            MoeLayer::native(
                cfg.clone(),
                cl.clone(),
                MoeLayerOptions { alltoall, dedup, chunks, ..Default::default() },
                seed,
            )
            .unwrap()
        };
        let mut rng = Rng::seed(seed ^ 0x5EED);
        let shards: Vec<Tensor> =
            (0..w).map(|_| Tensor::randn(&[tokens, d], &mut rng)).collect();

        let flat = mk(CommChoice::Flat, false, ChunkChoice::Fixed(1));
        let (fo, fr) = flat.forward(&shards).unwrap();
        for (dedup, chunks) in [
            (false, ChunkChoice::Fixed(1)),
            (true, ChunkChoice::Fixed(1)),
            (true, ChunkChoice::Fixed(3)),
            (true, ChunkChoice::Auto),
        ] {
            let hier = mk(CommChoice::Hierarchical, dedup, chunks);
            let (ho, hr) = hier.forward(&shards).unwrap();
            for (x, y) in fo.iter().zip(&ho) {
                assert!(
                    x.allclose(y, 0.0),
                    "case {}: {gate:?} nodes={nodes} gpus={gpus} dedup={dedup}: \
                     hierarchical output diverged by {}",
                    g.case,
                    x.max_abs_diff(y)
                );
            }
            assert_eq!(fr.expert_counts, hr.expert_counts, "case {}", g.case);
            assert_eq!(fr.drop_rate, hr.drop_rate, "case {}", g.case);
            assert_eq!(hr.comm_schedule, "hier", "case {}", g.case);
            // Honest split: flat and hier move the same *total* rows,
            // but hier routes same-node rows through the leader (two
            // intra hops) and dedup can only shave NIC bytes.
            assert!(
                hr.bytes_on_wire <= fr.bytes_on_wire,
                "case {}: hier NIC bytes {} must never exceed flat's {} \
                 (aggregation + dedup only remove NIC traffic)",
                g.case,
                hr.bytes_on_wire,
                fr.bytes_on_wire
            );
            if !dedup && nodes > 1 {
                // Without dedup every cross-node row crosses once under
                // either schedule: identical NIC bytes.
                assert_eq!(hr.bytes_on_wire, fr.bytes_on_wire, "case {}", g.case);
            }
            if nodes == 1 {
                assert_eq!(hr.bytes_on_wire, 0, "case {}: single node has no NIC", g.case);
                assert_eq!(fr.bytes_on_wire, 0, "case {}", g.case);
            }
        }
    });
}

/// Training path: gradients through the hierarchical transposed
/// exchanges (dy-dispatch dedup + dx-combine pre-summation) must match
/// the flat backward exactly — dx, router grads and every expert
/// parameter grad — including drop regimes and k ≥ 2 gates.
#[test]
fn hier_ragged_gradients_are_bit_identical_to_flat() {
    for_all(12, |g| {
        let nodes = g.usize_in(1..3) + 1; // 2..3 nodes: real NIC traffic
        let gpus = g.usize_in(1..3);
        let w = nodes * gpus;
        let epr = g.usize_in(1..3);
        let e = w * epr;
        let d = 8;
        let tokens = g.usize_in(4..20);
        let gate = gate_for(g.usize_in(0..4), e);
        let cf = *g.choose(&[0.5f64, 1.0, 2.0, 4.0]);
        let cfg = MoeConfig {
            num_experts: e,
            d_model: d,
            ffn_hidden: 16,
            capacity_factor: cf,
            gate: gate.clone(),
        };
        let cl = cluster(nodes, gpus);
        let seed = g.case as u64 + 4021;
        let mk = |alltoall, dedup, chunks| {
            TrainMoeLayer::native(
                cfg.clone(),
                cl.clone(),
                MoeLayerOptions { alltoall, dedup, chunks, ..Default::default() },
                seed,
            )
            .unwrap()
        };
        let mut rng = Rng::seed(seed ^ 0xFADE);
        let shards: Vec<Tensor> =
            (0..w).map(|_| Tensor::randn(&[tokens, d], &mut rng)).collect();
        let dy: Vec<Tensor> =
            (0..w).map(|_| Tensor::randn(&[tokens, d], &mut rng)).collect();

        let flat = mk(CommChoice::Flat, false, ChunkChoice::Fixed(1));
        let (fo, _, fc) = flat.forward_t(&shards, 0).unwrap();
        let (fdx, fg, fbwd) = flat.backward(&shards, &dy, &fc, 0.01).unwrap();

        for (dedup, chunks) in [
            (false, ChunkChoice::Fixed(1)),
            (true, ChunkChoice::Fixed(1)),
            (true, ChunkChoice::Auto),
        ] {
            let hier = mk(CommChoice::Hierarchical, dedup, chunks);
            let (ho, _, hc) = hier.forward_t(&shards, 0).unwrap();
            for (x, y) in fo.iter().zip(&ho) {
                assert!(x.allclose(y, 0.0), "case {}: {gate:?} fwd dedup={dedup}", g.case);
            }
            let (hdx, hg, hbwd) = hier.backward(&shards, &dy, &hc, 0.01).unwrap();
            for (x, y) in fdx.iter().zip(&hdx) {
                assert!(
                    x.allclose(y, 0.0),
                    "case {}: {gate:?} cf={cf} dedup={dedup}: dx diverged by {}",
                    g.case,
                    x.max_abs_diff(y)
                );
            }
            for (x, y) in fg.d_gate_weight.iter().zip(&hg.d_gate_weight) {
                assert!(x.allclose(y, 0.0), "case {}: {gate:?}: d_gate_weight", g.case);
            }
            for (x, y) in fg.experts.iter().zip(&hg.experts) {
                assert!(x.dw1.allclose(&y.dw1, 0.0), "case {}: {gate:?}: dw1", g.case);
                assert!(x.dw2.allclose(&y.dw2, 0.0), "case {}: {gate:?}: dw2", g.case);
                for (u, v) in x.db1.iter().zip(&y.db1) {
                    assert!((u - v).abs() == 0.0, "case {}: {gate:?}: db1", g.case);
                }
                for (u, v) in x.db2.iter().zip(&y.db2) {
                    assert!((u - v).abs() == 0.0, "case {}: {gate:?}: db2", g.case);
                }
            }
            // The backward exchanges never cross more NIC bytes than
            // the flat backward (pre-summation only removes traffic).
            assert!(hbwd.bytes_on_wire <= fbwd.bytes_on_wire, "case {}", g.case);
        }
    });
}

/// The inference layer and the training layer keep agreeing bitwise
/// when the hierarchical data path runs (same executor, same RNG
/// stream) — the dedup machinery must not split the two paths.
#[test]
fn inference_and_training_forward_agree_under_hier_dedup() {
    let cfg = MoeConfig {
        num_experts: 8,
        d_model: 16,
        ffn_hidden: 32,
        capacity_factor: 1.5,
        gate: GateKind::GShard,
    };
    let cl = cluster(2, 2);
    let opts = MoeLayerOptions {
        alltoall: CommChoice::Hierarchical,
        dedup: true,
        ..Default::default()
    };
    let layer = MoeLayer::native(cfg.clone(), cl.clone(), opts.clone(), 99).unwrap();
    let train = TrainMoeLayer::native(cfg, cl, opts, 99).unwrap();
    let mut rng = Rng::seed(313);
    let shards: Vec<Tensor> = (0..4).map(|_| Tensor::randn(&[12, 16], &mut rng)).collect();
    let (a, ra) = layer.forward(&shards).unwrap();
    let (b, rb, _) = train.forward_t(&shards, 0).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert!(x.allclose(y, 0.0));
    }
    assert_eq!(ra.comm_schedule, rb.comm_schedule);
    assert_eq!(ra.bytes_on_wire, rb.bytes_on_wire);
    assert_eq!(ra.bytes_intra_node, rb.bytes_intra_node);
}

/// k ≥ 2 with co-located replicas: dedup must strictly cut the NIC
/// bytes the step reports, while staying bit-identical (covered above).
#[test]
fn dedup_strictly_reduces_nic_bytes_for_k2() {
    let cfg = MoeConfig {
        num_experts: 8,
        d_model: 64,
        ffn_hidden: 64,
        capacity_factor: 4.0,
        gate: GateKind::GShard, // top-2
    };
    let cl = cluster(2, 2); // 4 experts per node: replicas often co-locate
    let mk = |dedup| {
        MoeLayer::native(
            cfg.clone(),
            cl.clone(),
            MoeLayerOptions {
                alltoall: CommChoice::Hierarchical,
                dedup,
                ..Default::default()
            },
            7,
        )
        .unwrap()
    };
    let mut rng = Rng::seed(55);
    let shards: Vec<Tensor> = (0..4).map(|_| Tensor::randn(&[64, 64], &mut rng)).collect();
    let (_, raw) = mk(false).forward(&shards).unwrap();
    let (_, ded) = mk(true).forward(&shards).unwrap();
    assert_eq!(raw.rows_deduped, 0);
    assert!(ded.rows_deduped > 0, "top-2 over 2 nodes must co-locate some replicas");
    assert!(
        ded.bytes_on_wire < raw.bytes_on_wire,
        "dedup must strictly cut NIC bytes: {} vs {}",
        ded.bytes_on_wire,
        raw.bytes_on_wire
    );
    // Intra-node traffic (gather/scatter of full rows) is untouched.
    assert_eq!(ded.bytes_intra_node, raw.bytes_intra_node);
}
