//! Integration tests across the full stack.
//!
//! The artifact-backed tests need `make artifacts` to have run (CI runs
//! `make test`, which guarantees it); they are skipped gracefully when
//! artifacts are absent so `cargo test` works on a fresh checkout too.

use hetumoe::config::{ClusterConfig, GateKind, MoeConfig};
use hetumoe::coordinator::Coordinator;
use hetumoe::moe::{CommImpl, GateImpl, LayoutImpl, MoeLayerOptions};
use hetumoe::tensor::Tensor;
use hetumoe::util::rng::Rng;

#[test]
fn full_pipeline_all_systems_agree_numerically() {
    // The four Fig-8 system profiles are different implementations of the
    // same math: outputs must agree bit-for-bit-ish.
    let moe = MoeConfig {
        num_experts: 8,
        d_model: 16,
        ffn_hidden: 32,
        capacity_factor: 8.0,
        gate: GateKind::Switch,
    };
    let cluster = ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) };
    let mut rng = Rng::seed(0);
    let shards: Vec<Tensor> =
        (0..4).map(|_| Tensor::randn(&[32, 16], &mut rng)).collect();
    let mut outputs = Vec::new();
    for kind in hetumoe::baselines::SystemKind::all() {
        let profile = hetumoe::baselines::SystemProfile::of(kind);
        let layer = hetumoe::moe::MoeLayer::native(
            moe.clone(),
            cluster.clone(),
            profile.options(1),
            7,
        )
        .unwrap();
        let (out, _) = layer.forward(&shards).unwrap();
        outputs.push(out);
    }
    for other in &outputs[1..] {
        for (a, b) in outputs[0].iter().zip(other) {
            assert!(a.allclose(b, 1e-4));
        }
    }
}

#[test]
fn coordinator_runs_every_gate_kind() {
    for gate in [
        GateKind::Switch,
        GateKind::GShard,
        GateKind::TopK { k: 2 },
        GateKind::KTop1 { k: 2 },
        GateKind::SamHTopK { groups: 2, k: 1 },
        GateKind::Base,
        GateKind::Hash { scheme: hetumoe::config::HashScheme::Random },
    ] {
        let moe = MoeConfig {
            num_experts: 4,
            d_model: 8,
            ffn_hidden: 16,
            capacity_factor: 2.0,
            gate: gate.clone(),
        };
        let cluster = ClusterConfig { nodes: 1, gpus_per_node: 2, ..ClusterConfig::commodity(1) };
        let mut coord =
            Coordinator::new(moe, cluster, MoeLayerOptions::default(), 64, 16, 0).unwrap();
        let summary = coord.run(2).unwrap();
        assert_eq!(summary.steps, 2, "{gate:?}");
        assert!(summary.last_output_norm.is_finite());
    }
}

#[test]
fn hierarchical_option_equals_flat_option_outputs() {
    let moe = MoeConfig {
        num_experts: 4,
        d_model: 8,
        ffn_hidden: 16,
        capacity_factor: 4.0,
        gate: GateKind::GShard,
    };
    let cluster = ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) };
    let mut rng = Rng::seed(1);
    let shards: Vec<Tensor> = (0..4).map(|_| Tensor::randn(&[16, 8], &mut rng)).collect();
    let mut outs = Vec::new();
    for comm_impl in [CommImpl::Flat, CommImpl::Hierarchical] {
        let opts = MoeLayerOptions {
            comm_impl,
            gate_impl: GateImpl::Fast,
            layout_impl: LayoutImpl::Optimized,
            dispatch: hetumoe::moe::DispatchMode::Padded,
            threads: 1,
            ..Default::default()
        };
        let layer =
            hetumoe::moe::MoeLayer::native(moe.clone(), cluster.clone(), opts, 3).unwrap();
        outs.push(layer.forward(&shards).unwrap().0);
    }
    for (a, b) in outs[0].iter().zip(&outs[1]) {
        assert_eq!(a, b, "comm flavor must not change results");
    }
}

// ---- artifact-backed (require `make artifacts` + `--features pjrt`) ----

#[cfg(feature = "pjrt")]
mod pjrt_backed {
    use super::*;
    use hetumoe::config::TrainConfig;
    use hetumoe::runtime::RuntimeClient;
    use hetumoe::train::Trainer;

    fn artifacts_available() -> bool {
        std::path::Path::new("artifacts/meta.json").exists()
    }

    #[test]
    fn runtime_loads_and_runs_gate_scores_artifact() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = RuntimeClient::cpu("artifacts").unwrap();
        let gate = rt.runner("gate_scores").unwrap();
        let t = gate.meta.inputs[0][0];
        let d = gate.meta.inputs[0][1];
        let e = gate.meta.attr_usize("num_experts").unwrap();
        let mut rng = Rng::seed(2);
        let x = Tensor::randn(&[t, d], &mut rng);
        let gw = Tensor::randn(&[d, e], &mut rng);
        let outs = gate.run(&[x.clone(), gw.clone()]).unwrap();
        assert_eq!(outs.len(), 3);
        assert_eq!(outs[0].shape(), &[t, e]);
        // The artifact's Pallas top-1 matches the native top-1.
        let native_scores = hetumoe::nn::matmul(&x, &gw);
        assert!(outs[0].allclose(&native_scores, 1e-3));
        let (ids, _) = hetumoe::gating::topk::topk_rows(&native_scores, 1, 1);
        for i in 0..t {
            assert_eq!(ids[i], outs[1].data()[i] as u32, "token {i}");
        }
    }

    #[test]
    fn runtime_shape_validation_errors() {
        if !artifacts_available() {
            return;
        }
        let mut rt = RuntimeClient::cpu("artifacts").unwrap();
        let gate = rt.runner("gate_scores").unwrap();
        // Wrong arity.
        assert!(gate.run(&[Tensor::zeros(&[1, 1])]).is_err());
        // Wrong shape.
        let bad = vec![Tensor::zeros(&[3, 3]), Tensor::zeros(&[3, 3])];
        assert!(gate.run(&bad).is_err());
        // Unknown artifact.
        assert!(rt.runner("not_an_artifact").is_err());
    }

    #[test]
    fn tiny_trainer_reduces_loss_through_pjrt() {
        if !artifacts_available() {
            return;
        }
        let cfg = TrainConfig {
            steps: 15,
            model: "tiny".into(),
            log_every: 100,
            ..TrainConfig::default_run()
        };
        let mut trainer = Trainer::new(cfg).unwrap();
        assert!(trainer.num_params() > 50_000);
        let logs = trainer.run().unwrap();
        assert_eq!(logs.len(), 15);
        let first = logs.first().unwrap().loss;
        let last = logs.last().unwrap().loss;
        assert!(
            last < first,
            "loss must decrease through the artifact path: {first} → {last}"
        );
    }

    #[test]
    fn checkpoint_roundtrip_restores_exact_state() {
        if !artifacts_available() {
            return;
        }
        let cfg = TrainConfig {
            steps: 3,
            model: "tiny".into(),
            log_every: 100,
            ..TrainConfig::default_run()
        };
        let mut trainer = Trainer::new(cfg).unwrap();
        trainer.run().unwrap();
        let ckpt = std::env::temp_dir().join("hetu_test_ckpt.bin");
        trainer.save_checkpoint(&ckpt).unwrap();
        // Deterministic batch for the comparison step.
        let n = trainer.cfg.batch_size * trainer.cfg.seq_len;
        let x: Vec<u32> = (0..n as u32).map(|i| i % 100).collect();
        let y: Vec<u32> = (0..n as u32).map(|i| (i + 1) % 100).collect();
        let loss_a = trainer.train_step(&x, &y).unwrap();
        trainer.load_checkpoint(&ckpt).unwrap();
        let loss_b = trainer.train_step(&x, &y).unwrap();
        assert!((loss_a - loss_b).abs() < 1e-6, "{loss_a} vs {loss_b}");
        // Wrong-model checkpoints are rejected.
        let mut other = Trainer::new(TrainConfig {
            steps: 1,
            model: "tiny".into(),
            log_every: 100,
            ..TrainConfig::default_run()
        })
        .unwrap();
        other.cfg.model = "different".into();
        assert!(other.load_checkpoint(&ckpt).is_err());
        std::fs::remove_file(&ckpt).ok();
    }

    #[test]
    fn top1_pallas_artifact_matches_rust_kernel() {
        if !artifacts_available() {
            return;
        }
        let mut rt = RuntimeClient::cpu("artifacts").unwrap();
        let k = rt.runner("top1_pallas").unwrap();
        let t = k.meta.inputs[0][0];
        let e = k.meta.inputs[0][1];
        let mut rng = Rng::seed(3);
        let scores = Tensor::randn(&[t, e], &mut rng);
        let outs = k.run(&[scores.clone()]).unwrap();
        let (ids, vals) = hetumoe::gating::topk::topk_rows(&scores, 1, 1);
        for i in 0..t {
            assert_eq!(outs[1].data()[i] as u32, ids[i], "idx {i}");
            assert!((outs[0].data()[i] - vals[i]).abs() < 1e-5);
        }
    }
}
