//! Property tests for the micro-chunked comm/compute overlap: chunking
//! (and the pool-parallel expert stage it enables) never changes
//! results — outputs and gradients are bit-identical to the unchunked
//! pipeline across random configs, drop regimes, both dispatch modes
//! and k ∈ {1, 2} — and the critical-path wall never exceeds the
//! sum-of-phases wall it replaced.

use hetumoe::backprop::TrainMoeLayer;
use hetumoe::config::{ClusterConfig, GateKind, MoeConfig};
use hetumoe::moe::{DispatchMode, MoeLayer, MoeLayerOptions};
use hetumoe::pipeline::ChunkChoice;
use hetumoe::tensor::Tensor;
use hetumoe::util::proptest::for_all;
use hetumoe::util::rng::Rng;

fn cluster(nodes: usize, gpus: usize) -> ClusterConfig {
    ClusterConfig { nodes, gpus_per_node: gpus, ..ClusterConfig::commodity(nodes) }
}

#[test]
fn chunked_forward_is_bit_identical_and_critical_path_bounded() {
    for_all(18, |g| {
        let nodes = g.usize_in(1..3);
        let gpus = g.usize_in(1..3);
        let w = nodes * gpus;
        let epr = g.usize_in(1..3);
        let e = w * epr;
        let d = 4 * g.usize_in(1..3);
        let tokens = g.usize_in(4..24);
        let gate = match g.usize_in(0..3) {
            0 => GateKind::Switch,          // k = 1
            1 => GateKind::GShard,          // k = 2
            _ => GateKind::TopK { k: 2 },   // k = 2
        };
        let cfg = MoeConfig {
            num_experts: e,
            d_model: d,
            ffn_hidden: 2 * d,
            // Includes drop regimes (cf < 1) and generous capacity.
            capacity_factor: g.f32_in(0.4, 3.0) as f64,
            gate: gate.clone(),
        };
        let dispatch =
            if g.usize_in(0..2) == 0 { DispatchMode::Ragged } else { DispatchMode::Padded };
        let n_chunks = g.usize_in(2..6);
        let threads = g.usize_in(1..4);
        let cl = cluster(nodes, gpus);
        let seed = g.case as u64 + 211;

        let base = MoeLayer::native(
            cfg.clone(),
            cl.clone(),
            MoeLayerOptions {
                dispatch,
                chunks: ChunkChoice::Fixed(1),
                threads: 1,
                ..Default::default()
            },
            seed,
        )
        .unwrap();
        let chunked = MoeLayer::native(
            cfg,
            cl,
            MoeLayerOptions {
                dispatch,
                chunks: ChunkChoice::Fixed(n_chunks),
                threads,
                ..Default::default()
            },
            seed,
        )
        .unwrap();

        let mut rng = Rng::seed(seed ^ 0xC0FFEE);
        let shards: Vec<Tensor> =
            (0..w).map(|_| Tensor::randn(&[tokens, d], &mut rng)).collect();
        let (a, ra) = base.forward(&shards).unwrap();
        let (b, rb) = chunked.forward(&shards).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!(
                x.allclose(y, 0.0),
                "case {}: {gate:?} {dispatch:?} n={n_chunks} threads={threads}: \
                 chunked output diverged by {}",
                g.case,
                x.max_abs_diff(y)
            );
        }
        assert_eq!(ra.expert_counts, rb.expert_counts, "case {}", g.case);
        assert_eq!(ra.drop_rate, rb.drop_rate, "case {}", g.case);
        assert_eq!(ra.bytes_on_wire, rb.bytes_on_wire, "case {}", g.case);
        assert_eq!(ra.comm_schedule, rb.comm_schedule, "case {}", g.case);

        // Unchunked: everything exposed, nothing hidden.
        assert_eq!(ra.n_chunks, 1, "case {}", g.case);
        assert_eq!(ra.comm_hidden, 0.0, "case {}", g.case);
        assert_eq!(ra.overlap_efficiency(), 0.0, "case {}", g.case);

        // Both reports: the critical path of the overlapped region never
        // exceeds the serial sum of its phases (expert + both legs), and
        // the exposure split is consistent.
        for (label, rep) in [("base", &ra), ("chunked", &rb)] {
            let serial = rep.wall_phase("expert") + rep.comm_total();
            assert!(
                rep.critical_path <= serial + 1e-9,
                "case {} ({label}): critical path {} > serial sum {}",
                g.case,
                rep.critical_path,
                serial
            );
            assert!(rep.comm_exposed >= 0.0 && rep.compute_exposed >= 0.0);
            assert!(rep.comm_hidden >= 0.0);
            let eff = rep.overlap_efficiency();
            assert!((0.0..=1.0).contains(&eff), "case {} ({label}): eff={eff}");
            assert!(
                rep.critical_wall() <= rep.wall_total() + rep.comm_total() + 1e-9,
                "case {} ({label})",
                g.case
            );
        }
        if dispatch == DispatchMode::Padded {
            // The padded pipeline is never chunked.
            assert_eq!(rb.n_chunks, 1, "case {}", g.case);
        } else {
            // Effective chunk count after clamping to the chunkable
            // units and tiling them into equal contiguous groups: ranks
            // under the flat schedule, nodes under the hierarchical one
            // (node-axis chunking keeps the aggregated inter-node
            // messages and dedup groups whole).
            let units = if rb.comm_schedule == "hier" { nodes } else { w };
            let per = units.div_ceil(n_chunks.clamp(1, units));
            assert_eq!(rb.n_chunks, units.div_ceil(per), "case {}", g.case);
        }
    });
}

#[test]
fn chunked_gradients_are_bit_identical() {
    for_all(10, |g| {
        let gates = [GateKind::Switch, GateKind::TopK { k: 2 }, GateKind::GShard];
        let gate = g.choose(&gates).clone();
        let cf = *g.choose(&[0.5f64, 1.0, 2.0, 4.0]);
        let dispatch =
            if g.usize_in(0..2) == 0 { DispatchMode::Ragged } else { DispatchMode::Padded };
        let cfg = MoeConfig {
            num_experts: 8,
            d_model: 8,
            ffn_hidden: 16,
            capacity_factor: cf,
            gate: gate.clone(),
        };
        let cl = cluster(2, 2);
        let tokens = g.usize_in(4..20);
        let n_chunks = g.usize_in(2..5);
        let seed = g.case as u64 + 17;
        let mk = |chunks, threads| {
            TrainMoeLayer::native(
                cfg.clone(),
                cl.clone(),
                MoeLayerOptions { dispatch, chunks, threads, ..Default::default() },
                seed,
            )
            .unwrap()
        };
        let base = mk(ChunkChoice::Fixed(1), 1);
        let chunked = mk(ChunkChoice::Fixed(n_chunks), 2);

        let mut rng = Rng::seed(seed ^ 0xBEEF);
        let shards: Vec<Tensor> =
            (0..4).map(|_| Tensor::randn(&[tokens, 8], &mut rng)).collect();
        let dy: Vec<Tensor> =
            (0..4).map(|_| Tensor::randn(&[tokens, 8], &mut rng)).collect();

        let (ao, _, ac) = base.forward_t(&shards, 0).unwrap();
        let (bo, _, bc) = chunked.forward_t(&shards, 0).unwrap();
        for (x, y) in ao.iter().zip(&bo) {
            assert!(x.allclose(y, 0.0), "{gate:?} {dispatch:?} cf={cf}: forward");
        }
        let (adx, ag, abwd) = base.backward(&shards, &dy, &ac, 0.01).unwrap();
        let (bdx, bg, bbwd) = chunked.backward(&shards, &dy, &bc, 0.01).unwrap();
        for (x, y) in adx.iter().zip(&bdx) {
            assert!(x.allclose(y, 0.0), "{gate:?} {dispatch:?} cf={cf}: dx");
        }
        for (x, y) in ag.d_gate_weight.iter().zip(&bg.d_gate_weight) {
            assert!(x.allclose(y, 0.0), "{gate:?} cf={cf}: d_gate_weight");
        }
        for (x, y) in ag.experts.iter().zip(&bg.experts) {
            assert!(x.dw1.allclose(&y.dw1, 0.0), "{gate:?} cf={cf}: dw1");
            assert!(x.dw2.allclose(&y.dw2, 0.0), "{gate:?} cf={cf}: dw2");
            for (u, v) in x.db1.iter().zip(&y.db1) {
                assert!((u - v).abs() == 0.0, "{gate:?} cf={cf}: db1");
            }
            for (u, v) in x.db2.iter().zip(&y.db2) {
                assert!((u - v).abs() == 0.0, "{gate:?} cf={cf}: db2");
            }
        }
        // The backward region obeys the same critical-path bound.
        for (label, rep) in [("base", &abwd), ("chunked", &bbwd)] {
            let serial = rep.wall_phase("bwd_expert")
                + rep
                    .comm
                    .iter()
                    .filter(|(n, _)| n.starts_with("alltoall_"))
                    .map(|(_, t)| t)
                    .sum::<f64>();
            assert!(
                rep.critical_path <= serial + 1e-9,
                "case {} ({label}): bwd critical path {} > serial {}",
                g.case,
                rep.critical_path,
                serial
            );
        }
        assert_eq!(abwd.bytes_on_wire, bbwd.bytes_on_wire);
    });
}

#[test]
fn auto_chunking_also_stays_bit_identical() {
    // `--chunks auto` (the default) against forced single-chunk, with
    // pool-parallel experts: same outputs, sane report.
    let cfg = MoeConfig {
        num_experts: 8,
        d_model: 16,
        ffn_hidden: 32,
        capacity_factor: 1.5,
        gate: GateKind::Switch,
    };
    let cl = cluster(2, 2);
    let mk = |chunks, threads| {
        MoeLayer::native(
            cfg.clone(),
            cl.clone(),
            MoeLayerOptions { chunks, threads, ..Default::default() },
            77,
        )
        .unwrap()
    };
    let base = mk(ChunkChoice::Fixed(1), 1);
    let auto = mk(ChunkChoice::Auto, 4);
    let mut rng = Rng::seed(123);
    let shards: Vec<Tensor> = (0..4).map(|_| Tensor::randn(&[32, 16], &mut rng)).collect();
    let (a, ra) = base.forward(&shards).unwrap();
    let (b, rb) = auto.forward(&shards).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert!(x.allclose(y, 0.0));
    }
    assert!(rb.n_chunks >= 1 && rb.n_chunks <= 4);
    assert_eq!(ra.comm_schedule, rb.comm_schedule);
    // Auto never models a worse wall than the unchunked plan it also
    // evaluated (comm legs are simulated, so this comparison is exact
    // up to the measured compute profile each run saw).
    assert!(rb.critical_path <= rb.wall_phase("expert") + rb.comm_total() + 1e-9);
}
