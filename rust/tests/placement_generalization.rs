//! Property tests: every placement-dependent site must stay correct
//! under *arbitrary* expert→rank tables — permutations, uneven hosts,
//! and the elastic dead-rank composition — not just the contiguous
//! `e/(E/W)` formula the paper starts from.
//!
//! Three surfaces are exercised (the ones the adaptive optimizer's
//! deltas actually flow through):
//!
//! 1. [`DispatchPlan::rank_counts`] / `rank_counts_placed` — the traffic
//!    matrix rows must conserve kept tokens and agree with a manual
//!    collapse of the table, healthy or degraded.
//! 2. [`dedup_traffic`] — node-pair row totals must match the placed
//!    traffic matrix aggregated by node, with `payloads ≤ heads ≤ rows`
//!    elementwise (the dedup ladder) under any table.
//! 3. [`pick_schedule`] — deterministic, tie-breaks to Flat, and the
//!    chosen legs always sum to the chosen schedule's round trip, for
//!    arbitrary (including replica-spread) count matrices.

use hetumoe::cluster::{ExpertPlacement, NetworkModel};
use hetumoe::comm::schedule::transpose_counts;
use hetumoe::comm::{dedup_traffic, pick_schedule, CommChoice, Schedule};
use hetumoe::config::ClusterConfig;
use hetumoe::gating::{apply_capacity, DispatchPlan, Routing};
use hetumoe::util::proptest::{for_all, Gen};

/// Random routing over `e` experts: `tokens × k` slots, ~10% inactive.
fn routing(g: &mut Gen, e: usize) -> Routing {
    let tokens = g.usize_in(1..40);
    let k = g.usize_in(1..3);
    let slots = tokens * k;
    let expert_ids: Vec<u32> = (0..slots).map(|_| g.u32_in(0..e as u32)).collect();
    let weights: Vec<f32> =
        (0..slots).map(|_| if g.bool_with(0.1) { 0.0 } else { 1.0 }).collect();
    let r = Routing { k, tokens, num_experts: e, expert_ids, weights, aux_loss: 0.0 };
    r.validate().expect("generated routing is internally consistent");
    r
}

fn plan(g: &mut Gen, e: usize) -> DispatchPlan {
    let r = routing(g, e);
    let capacity = g.usize_in(1..r.tokens + 1);
    apply_capacity(&r, capacity)
}

/// Random expert→rank table (arbitrary: permuted, uneven, maybe even
/// contiguous — `from_table` normalizes that case and it must still
/// hold).
fn table(g: &mut Gen, e: usize, w: usize) -> Vec<usize> {
    (0..e).map(|_| g.usize_in(0..w)).collect()
}

/// Random strict subset of dead ranks (at least one survivor).
fn dead_ranks(g: &mut Gen, w: usize) -> Vec<usize> {
    let mut dead: Vec<usize> = (0..w).filter(|_| g.bool_with(0.3)).collect();
    if dead.len() == w {
        dead.pop();
    }
    dead
}

#[test]
fn rank_counts_conserve_tokens_under_any_table() {
    for_all(128, |g| {
        let w = *g.choose(&[2usize, 4]);
        let e = w * g.usize_in(1..4);
        let p = plan(g, e);
        let kept_total: usize = p.kept.iter().sum();

        // Contiguous: the convenience wrapper and the placed form agree.
        assert_eq!(p.rank_counts(w), p.rank_counts_placed(&ExpertPlacement::new(e, w)));

        // Arbitrary table: conservation + manual collapse.
        let t = table(g, e, w);
        let placed = ExpertPlacement::from_table(e, w, &t);
        let counts = p.rank_counts_placed(&placed);
        assert_eq!(counts.len(), w);
        assert_eq!(counts.iter().sum::<usize>(), kept_total, "tokens lost by the table");
        for (r, &c) in counts.iter().enumerate() {
            let manual: usize =
                (0..e).filter(|&ex| t[ex] == r).map(|ex| p.kept[ex]).sum();
            assert_eq!(c, manual, "rank {r} disagrees with a manual collapse of {t:?}");
        }

        // Dead-rank composition: still conserved, dead columns empty.
        let dead = dead_ranks(g, w);
        let degraded = placed.compose_dead(&dead);
        let counts = p.rank_counts_placed(&degraded);
        assert_eq!(counts.iter().sum::<usize>(), kept_total, "tokens lost by the remap");
        for &r in &dead {
            assert_eq!(counts[r], 0, "dead rank {r} still receives tokens");
        }
        // resolve() is the same composition the layer/router/executor use.
        assert_eq!(degraded, ExpertPlacement::resolve(e, w, Some(&t), &dead));
    });
}

#[test]
fn dedup_traffic_matches_the_placed_matrix_under_any_table() {
    for_all(96, |g| {
        let nodes = 2usize;
        let gpus = *g.choose(&[1usize, 2]);
        let cluster =
            ClusterConfig { nodes, gpus_per_node: gpus, ..ClusterConfig::commodity(nodes) };
        let w = nodes * gpus;
        let e = w * g.usize_in(1..3);
        let plans: Vec<DispatchPlan> = (0..w).map(|_| plan(g, e)).collect();
        let t = table(g, e, w);
        let placed = ExpertPlacement::from_table(e, w, &t);
        let traffic = dedup_traffic(plans.iter(), &placed, &cluster);

        let kept_total: usize =
            plans.iter().map(|p| p.kept.iter().sum::<usize>()).sum();
        let rows_total: usize =
            traffic.rows.iter().map(|r| r.iter().sum::<usize>()).sum();
        assert_eq!(rows_total, kept_total, "dedup rows must count every kept slot");

        for sn in 0..nodes {
            for dn in 0..nodes {
                // The dedup ladder: unique payloads ≤ run heads ≤ rows.
                assert!(traffic.payloads[sn][dn] <= traffic.heads[sn][dn]);
                assert!(traffic.heads[sn][dn] <= traffic.rows[sn][dn]);
                // Node-pair rows equal the placed rank matrix aggregated
                // by node — dedup and the schedule pick see one truth.
                let manual: usize = (sn * gpus..(sn + 1) * gpus)
                    .map(|s| {
                        let row = plans[s].rank_counts_placed(&placed);
                        row[dn * gpus..(dn + 1) * gpus].iter().sum::<usize>()
                    })
                    .sum();
                assert_eq!(traffic.rows[sn][dn], manual, "node pair ({sn},{dn})");
            }
        }
    });
}

#[test]
fn pick_schedule_is_deterministic_and_honors_the_tie_break() {
    for_all(96, |g| {
        let nodes = 2usize;
        let gpus = *g.choose(&[1usize, 2]);
        let mut cfg = ClusterConfig::commodity(nodes);
        cfg.gpus_per_node = gpus;
        let net = NetworkModel::new(cfg);
        let w = nodes * gpus;
        // Arbitrary counts — this is what a permuted table or a replica
        // spread produces: any non-negative matrix is reachable.
        let counts: Vec<Vec<usize>> =
            (0..w).map(|_| (0..w).map(|_| g.usize_in(0..200)).collect()).collect();
        let elem_bytes = *g.choose(&[4usize, 256, 1024]);

        let pick = pick_schedule(&net, &counts, elem_bytes, CommChoice::Auto);
        // Deterministic: same inputs, same pick.
        let again = pick_schedule(&net, &counts, elem_bytes, CommChoice::Auto);
        assert_eq!(pick.schedule, again.schedule);
        assert_eq!(pick.flat_time, again.flat_time);
        assert_eq!(pick.hier_time, again.hier_time);

        // Auto takes the strictly cheaper round trip; ties go Flat.
        if pick.hier_time < pick.flat_time {
            assert_eq!(pick.schedule, Schedule::Hierarchical);
        } else {
            assert_eq!(pick.schedule, Schedule::Flat);
        }
        // The reported legs always sum to the chosen round trip.
        let chosen = match pick.schedule {
            Schedule::Flat => pick.flat_time,
            Schedule::Hierarchical => pick.hier_time,
        };
        assert_eq!(pick.dispatch_time + pick.combine_time, chosen);

        // Forced policies never consult the costs.
        let flat = pick_schedule(&net, &counts, elem_bytes, CommChoice::Flat);
        assert_eq!(flat.schedule, Schedule::Flat);
        assert_eq!(flat.dispatch_time + flat.combine_time, flat.flat_time);
        let hier = pick_schedule(&net, &counts, elem_bytes, CommChoice::Hierarchical);
        assert_eq!(hier.schedule, Schedule::Hierarchical);
        assert_eq!(hier.dispatch_time + hier.combine_time, hier.hier_time);

        // The combine leg is the transposed dispatch leg: scoring the
        // transposed matrix swaps the two legs of the flat schedule.
        let t = transpose_counts(&counts);
        let flat_t = pick_schedule(&net, &t, elem_bytes, CommChoice::Flat);
        assert_eq!(flat_t.flat_time, flat.flat_time, "flat round trip is transpose-invariant");
    });
}
