//! Property tests: the ragged (padding-free) dispatch pipeline is
//! observationally identical to the padded baseline — bit-identical
//! outputs, identical routing statistics — while moving strictly fewer
//! bytes and reporting zero padding waste.

use hetumoe::config::{ClusterConfig, GateKind, MoeConfig};
use hetumoe::moe::{DispatchMode, MoeLayer, MoeLayerOptions};
use hetumoe::tensor::Tensor;
use hetumoe::util::proptest::for_all;
use hetumoe::util::rng::Rng;

fn cluster(nodes: usize, gpus: usize) -> ClusterConfig {
    ClusterConfig { nodes, gpus_per_node: gpus, ..ClusterConfig::commodity(nodes) }
}

fn layer(
    cfg: &MoeConfig,
    cl: &ClusterConfig,
    dispatch: DispatchMode,
    threads: usize,
    seed: u64,
) -> MoeLayer {
    let opts = MoeLayerOptions { dispatch, threads, ..Default::default() };
    MoeLayer::native(cfg.clone(), cl.clone(), opts, seed).unwrap()
}

#[test]
fn ragged_equals_padded_property() {
    // Random gates, world sizes, capacity factors (drops allowed — both
    // pipelines share the same capacity plan, so they must agree even
    // when tokens are dropped).
    for_all(24, |g| {
        let nodes = g.usize_in(1..3);
        let gpus = g.usize_in(1..3);
        let w = nodes * gpus;
        let epr = g.usize_in(2..4);
        let e = w * epr;
        let d = 4 * g.usize_in(1..3);
        let tokens = g.usize_in(4..24);
        let gate = match g.usize_in(0..3) {
            0 => GateKind::Switch,
            1 => GateKind::GShard,
            _ => GateKind::TopK { k: 2 },
        };
        let cfg = MoeConfig {
            num_experts: e,
            d_model: d,
            ffn_hidden: 2 * d,
            capacity_factor: g.f32_in(0.4, 3.0) as f64,
            gate: gate.clone(),
        };
        let cl = cluster(nodes, gpus);
        let threads = g.usize_in(1..3);
        let seed = g.case as u64 + 101;
        let padded = layer(&cfg, &cl, DispatchMode::Padded, 1, seed);
        let ragged = layer(&cfg, &cl, DispatchMode::Ragged, threads, seed);

        let mut rng = Rng::seed(seed ^ 0xF00D);
        let shards: Vec<Tensor> =
            (0..w).map(|_| Tensor::randn(&[tokens, d], &mut rng)).collect();
        let (a, pr) = padded.forward(&shards).unwrap();
        let (b, rr) = ragged.forward(&shards).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!(
                x.allclose(y, 0.0),
                "case {}: {gate:?} {nodes}x{gpus} E={e} outputs diverged by {}",
                g.case,
                x.max_abs_diff(y)
            );
        }
        assert_eq!(pr.expert_counts, rr.expert_counts, "case {}", g.case);
        assert_eq!(pr.drop_rate, rr.drop_rate, "case {}", g.case);
        assert!(
            rr.bytes_on_wire <= pr.bytes_on_wire,
            "case {}: ragged moved {} bytes, padded {}",
            g.case,
            rr.bytes_on_wire,
            pr.bytes_on_wire
        );
        assert!(rr.expert_flops <= pr.expert_flops, "case {}", g.case);
    });
}

#[test]
fn ragged_reports_zero_padding_waste_when_capacity_unbounded() {
    for_all(8, |g| {
        let nodes = g.usize_in(1..3);
        let gpus = g.usize_in(1..3);
        let w = nodes * gpus;
        let e = 2 * w;
        let tokens = g.usize_in(4..32);
        let cfg = MoeConfig {
            num_experts: e,
            d_model: 8,
            ffn_hidden: 16,
            // cap = ceil(tokens·k/E · cf) ≥ tokens·k: nothing can drop.
            capacity_factor: e as f64 + 1.0,
            gate: GateKind::Switch,
        };
        let cl = cluster(nodes, gpus);
        let ragged = layer(&cfg, &cl, DispatchMode::Ragged, 1, g.case as u64);
        let padded = layer(&cfg, &cl, DispatchMode::Padded, 1, g.case as u64);
        let mut rng = Rng::seed(g.case as u64 + 7);
        let shards: Vec<Tensor> =
            (0..w).map(|_| Tensor::randn(&[tokens, 8], &mut rng)).collect();
        let (_, rr) = ragged.forward(&shards).unwrap();
        let (_, pr) = padded.forward(&shards).unwrap();
        assert_eq!(rr.drop_rate, 0.0, "unbounded capacity must not drop");
        assert_eq!(rr.padding_waste, 0.0, "ragged buffers hold only occupied rows");
        assert!(
            pr.padding_waste > 0.0,
            "the padded pipeline pads heavily at unbounded capacity"
        );
    });
}
