//! Integration tests for the serving subsystem, including the routing
//! contract against the training pipeline and the comm-equivalence
//! property the serving router's cost model relies on.

use hetumoe::cluster::NetworkModel;
use hetumoe::comm::alltoall::{alltoall, alltoallv_timing, flat_alltoall_timing};
use hetumoe::comm::hier_ragged::dedup_traffic;
use hetumoe::comm::hierarchical::{
    hierarchical_alltoall, hierarchical_alltoallv_timing, hierarchical_alltoall_timing,
};
use hetumoe::config::{ClusterConfig, GateKind, MoeConfig};
use hetumoe::gating::apply_capacity;
use hetumoe::moe::{MoeLayer, MoeLayerOptions};
use hetumoe::serve::{
    ArrivalProcess, CommChoice, PlacementRouter, ServeConfig, ServeEngine,
};
use hetumoe::tensor::Tensor;
use hetumoe::util::proptest::for_all;
use hetumoe::util::rng::Rng;

fn cluster(nodes: usize, gpus: usize) -> ClusterConfig {
    ClusterConfig { nodes, gpus_per_node: gpus, ..ClusterConfig::commodity(nodes) }
}

/// The acceptance contract: on identical token batches, the serving
/// router must produce exactly the routing and capacity placement the
/// training-path `MoeLayer` computes.
#[test]
fn serving_routing_agrees_with_training_dispatch() {
    for gate in [GateKind::Switch, GateKind::GShard, GateKind::TopK { k: 2 }] {
        let moe = MoeConfig {
            num_experts: 8,
            d_model: 16,
            ffn_hidden: 32,
            capacity_factor: 1.5,
            gate: gate.clone(),
        };
        let cl = cluster(2, 2);
        let layer =
            MoeLayer::native(moe.clone(), cl.clone(), MoeLayerOptions::default(), 11)
                .unwrap();
        // Share the training layer's router weight + gate config.
        let router = PlacementRouter::from_layer(&layer, CommChoice::Auto).unwrap();

        let mut rng = Rng::seed(21);
        let shard = Tensor::randn(&[24, 16], &mut rng);

        // Training-path routing on the shard.
        let scores = hetumoe::nn::matmul(&shard, &layer.gate_weight);
        let expected = layer.gate.route_scores(&scores, 0);
        let cap = moe.capacity(shard.rows());
        let expected_plan = apply_capacity(&expected, cap);

        // Serving-path routing on the identical shard.
        let (routing, plan) = router.route_shard(&shard, 0);

        assert_eq!(routing.expert_ids, expected.expert_ids, "{gate:?}");
        assert_eq!(routing.weights, expected.weights, "{gate:?}");
        assert_eq!(plan.dest, expected_plan.dest, "{gate:?}");
        assert_eq!(plan.kept, expected_plan.kept, "{gate:?}");
        assert_eq!(plan.capacity, expected_plan.capacity, "{gate:?}");
    }
}

/// The batch path must agree with the per-shard path (and therefore
/// with training) for every full shard of a sharded batch.
#[test]
fn batch_routing_decomposes_into_training_shards() {
    let moe = MoeConfig {
        num_experts: 8,
        d_model: 16,
        ffn_hidden: 32,
        capacity_factor: 2.0,
        gate: GateKind::Switch,
    };
    let cl = cluster(2, 2);
    let layer =
        MoeLayer::native(moe.clone(), cl.clone(), MoeLayerOptions::default(), 5).unwrap();
    let mut router = PlacementRouter::from_layer(&layer, CommChoice::Auto).unwrap();
    let mut rng = Rng::seed(31);
    let batch = Tensor::randn(&[32, 16], &mut rng); // 8 tokens per rank
    let decision = router.route_batch(&batch, 0);
    assert_eq!(decision.shards.len(), 4);
    for (r, (routing, plan)) in decision.shards.iter().enumerate() {
        let shard = batch.slice_rows(r * 8, (r + 1) * 8);
        let (exp_routing, exp_plan) = router.route_shard(&shard, 0);
        assert_eq!(routing.expert_ids, exp_routing.expert_ids, "shard {r}");
        assert_eq!(plan.dest, exp_plan.dest, "shard {r}");
    }
    // Expert counts must match what the training layer reports for the
    // same shards.
    let shards: Vec<Tensor> = (0..4).map(|r| batch.slice_rows(r * 8, (r + 1) * 8)).collect();
    let (_, report) = layer.forward(&shards).unwrap();
    let demanded: Vec<usize> = report.expert_counts.clone();
    // The router's counts are post-capacity; every kept count is bounded
    // by the demanded count and nothing is routed to an expert training
    // never picked.
    for (e, (&kept, &demand)) in
        decision.expert_counts.iter().zip(&demanded).enumerate()
    {
        assert!(kept <= demand, "expert {e}: kept {kept} > demanded {demand}");
        if demand == 0 {
            assert_eq!(kept, 0, "expert {e} routed without demand");
        }
    }
}

/// Satellite property: hierarchical AllToAll is bit-identical to the
/// flat permutation across random world sizes and payloads.
#[test]
fn hierarchical_matches_flat_bitwise_across_random_worlds() {
    for_all(24, |g| {
        let nodes = g.usize_in(1..6);
        let gpus = g.usize_in(1..6);
        let chunk = g.usize_in(1..8);
        let net = NetworkModel::new(cluster(nodes, gpus));
        let w = nodes * gpus;
        let mut a: Vec<Vec<f32>> = (0..w)
            .map(|_| (0..w * chunk).map(|_| g.normal()).collect())
            .collect();
        let mut b = a.clone();
        alltoall(&net, &mut a).unwrap();
        hierarchical_alltoall(&net, &mut b).unwrap();
        assert_eq!(a, b, "nodes={nodes} gpus={gpus} chunk={chunk}");
    });
}

/// The ragged cost models agree with the equal-chunk cost models on
/// uniform traffic across random worlds (so the serving router's
/// per-batch scores are consistent with the training-side figures).
#[test]
fn ragged_cost_models_reduce_to_uniform_across_random_worlds() {
    for_all(16, |g| {
        let nodes = g.usize_in(1..5);
        let gpus = g.usize_in(1..5);
        let chunk = g.usize_in(1..512);
        let net = NetworkModel::new(cluster(nodes, gpus));
        let w = nodes * gpus;
        let counts = vec![vec![chunk; w]; w];
        let flat_v = alltoallv_timing(&net, &counts, 4).total;
        let flat = flat_alltoall_timing(&net, chunk * 4).total;
        assert!((flat_v - flat).abs() < 1e-9, "flat {flat} vs ragged {flat_v}");
        let hier_v = hierarchical_alltoallv_timing(&net, &counts, 4).total;
        let hier = hierarchical_alltoall_timing(&net, chunk * 4).total;
        assert!((hier_v - hier).abs() < 1e-9, "hier {hier} vs ragged {hier_v}");
    });
}

/// End-to-end serving smoke across gate × comm configurations.
#[test]
fn serving_runs_across_gate_and_comm_configs() {
    for gate in [GateKind::Switch, GateKind::GShard] {
        for comm in [CommChoice::Flat, CommChoice::Hierarchical, CommChoice::Auto] {
            let cfg = ServeConfig {
                moe: MoeConfig {
                    num_experts: 8,
                    d_model: 16,
                    ffn_hidden: 32,
                    capacity_factor: 1.5,
                    gate: gate.clone(),
                },
                cluster: cluster(2, 2),
                process: ArrivalProcess::Poisson { rate: 400.0 },
                comm,
                duration: 0.25,
                ..ServeConfig::default_run()
            };
            // Ground truth from an identical generator: conservation is
            // checked against the real arrival count, not the report's
            // own bookkeeping.
            let ground_truth = hetumoe::serve::WorkloadGen::new(
                cfg.process.clone(),
                cfg.min_tokens,
                cfg.max_tokens,
                cfg.slo,
                cfg.seed,
            )
            .generate(cfg.duration)
            .len();
            let mut engine = ServeEngine::new(cfg).unwrap();
            let report = engine.run().unwrap();
            assert!(report.offered > 0, "{gate:?}/{comm:?}");
            assert_eq!(
                report.completed + report.dropped + report.rejected,
                ground_truth,
                "{gate:?}/{comm:?}: every generated request must be accounted for"
            );
            assert!(report.breakdown.total > 0.0, "{gate:?}/{comm:?}");
        }
    }
}

/// On the NIC-constrained commodity cluster the hierarchical schedule
/// must outperform flat for serving-sized batches end to end.
#[test]
fn hierarchical_beats_flat_under_nic_constrained_load() {
    let run = |comm: CommChoice| {
        let cfg = ServeConfig {
            cluster: ClusterConfig::commodity(2), // 2×8, one NIC per node
            process: ArrivalProcess::Poisson { rate: 2000.0 },
            comm,
            duration: 0.4,
            seed: 17,
            ..ServeConfig::default_run()
        };
        let mut engine = ServeEngine::new(cfg).unwrap();
        engine.run().unwrap()
    };
    let flat = run(CommChoice::Flat);
    let hier = run(CommChoice::Hierarchical);
    assert!(
        hier.latency.p95 < flat.latency.p95,
        "hier p95 {} must beat flat p95 {}",
        hier.latency.p95,
        flat.latency.p95
    );
    assert!(hier.goodput_tps >= flat.goodput_tps);
}

/// Tentpole contract of the ragged training pipeline: the per-step
/// AllToAll schedule the training layer picks is the same decision the
/// serving router makes for the identical traffic — both sides call
/// `comm::schedule::pick_schedule` on the same counts.
#[test]
fn training_ragged_schedule_matches_router_decision() {
    let moe = MoeConfig {
        num_experts: 8,
        d_model: 16,
        ffn_hidden: 32,
        capacity_factor: 1.5,
        gate: GateKind::Switch,
    };
    let cl = cluster(2, 2);
    let layer =
        MoeLayer::native(moe.clone(), cl.clone(), MoeLayerOptions::default(), 23)
            .unwrap();
    let mut router = PlacementRouter::from_layer(&layer, CommChoice::Auto).unwrap();

    let mut rng = Rng::seed(41);
    let batch = Tensor::randn(&[48, 16], &mut rng); // 12 tokens per rank
    let shards: Vec<Tensor> =
        (0..4).map(|r| batch.slice_rows(r * 12, (r + 1) * 12)).collect();

    let (_, report) = layer.forward(&shards).unwrap();
    let decision = router.route_batch(&batch, 0);
    let router_schedule = decision.comm.name();
    assert_eq!(
        report.comm_schedule, router_schedule,
        "training (ragged, auto) and serving must pick the same schedule \
         for the same traffic"
    );
}

/// Satellite contract of the dedup-aware schedule pick: the node-level
/// dedup counts the serving router scores are *exactly* what the
/// training side derives from the identical plans — same replica rows,
/// same unique payloads, same pre-summable runs — so the two
/// `pick_schedule_dedup` evaluations can never see different inputs
/// (and the documented flat tie-break makes equal inputs imply equal
/// picks, asserted above and re-asserted here under k = 2).
#[test]
fn dedup_aware_counts_are_what_both_sides_score() {
    let moe = MoeConfig {
        num_experts: 8,
        d_model: 16,
        ffn_hidden: 32,
        capacity_factor: 2.0,
        gate: GateKind::GShard, // k = 2: dedup actually has replicas
    };
    let cl = cluster(2, 2);
    let layer =
        MoeLayer::native(moe.clone(), cl.clone(), MoeLayerOptions::default(), 61).unwrap();
    assert!(layer.opts.dedup, "training scores dedup-aware counts by default");
    let mut router = PlacementRouter::from_layer(&layer, CommChoice::Auto).unwrap();
    assert!(router.dedup, "serving scores dedup-aware counts by default");

    let mut rng = Rng::seed(71);
    let batch = Tensor::randn(&[96, 16], &mut rng); // 24 tokens per rank
    let decision = router.route_batch(&batch, 0);

    // Training-side derivation from the identical routing: route every
    // shard exactly like the training pipeline, then collapse the plans
    // through the same `dedup_traffic` the executor uses.
    let placement = layer.placement();
    let plans: Vec<_> = (0..4)
        .map(|r| {
            let shard = batch.slice_rows(r * 24, (r + 1) * 24);
            let scores = hetumoe::nn::matmul(&shard, &layer.gate_weight);
            let routing = layer.gate.route_scores(&scores, 0);
            apply_capacity(&routing, moe.capacity(24))
        })
        .collect();
    let training_side = dedup_traffic(plans.iter(), &placement, &cl);
    assert_eq!(
        decision.dedup, training_side,
        "router and training executor must derive identical dedup counts"
    );
    // The summary is internally consistent: payloads ≤ heads ≤ rows,
    // and with k = 2 over 2 nodes some replicas must have co-located.
    let mut total_rows = 0usize;
    let mut total_payloads = 0usize;
    for sn in 0..2 {
        for dn in 0..2 {
            assert!(decision.dedup.payloads[sn][dn] <= decision.dedup.heads[sn][dn]);
            assert!(decision.dedup.heads[sn][dn] <= decision.dedup.rows[sn][dn]);
            total_rows += decision.dedup.rows[sn][dn];
            total_payloads += decision.dedup.payloads[sn][dn];
        }
    }
    let kept_total: usize =
        decision.shards.iter().map(|(_, p)| p.kept.iter().sum::<usize>()).sum();
    assert_eq!(total_rows, kept_total, "every kept row appears in the summary");
    assert!(total_payloads < total_rows, "top-2 routing must co-locate some replicas");

    // And the schedule pick still agrees with training under dedup.
    let shards: Vec<Tensor> =
        (0..4).map(|r| batch.slice_rows(r * 24, (r + 1) * 24)).collect();
    let (_, report) = layer.forward(&shards).unwrap();
    assert_eq!(report.comm_schedule, decision.comm.name());
    // StepReport's expert_counts are pre-capacity demand; the summary
    // counts kept rows only.
    assert!(report.expert_counts.iter().sum::<usize>() >= kept_total);
}
