//! Property tests for the AllToAllv timing cost models.
//!
//! The backward pass charges its exchanges on the *transposed* traffic
//! matrix through the same `alltoallv_timing` /
//! `hierarchical_alltoallv_timing` models the forward and the serving
//! router use, so these models carry real weight: they decide the
//! per-step flat-vs-hier schedule in both directions. Two properties
//! pin them down:
//!
//! 1. **Monotonicity** — adding traffic to any (src, dst) pair can
//!    never make the predicted exchange faster.
//! 2. **Uniform reduction** — on a uniform traffic matrix they reduce
//!    exactly to the equal-chunk formulas (`flat_alltoall_timing` /
//!    `hierarchical_alltoall_timing`).

use hetumoe::cluster::NetworkModel;
use hetumoe::comm::alltoall::{alltoallv_timing, flat_alltoall_timing};
use hetumoe::comm::hierarchical::{hierarchical_alltoall_timing, hierarchical_alltoallv_timing};
use hetumoe::comm::schedule::transpose_counts;
use hetumoe::config::ClusterConfig;
use hetumoe::util::proptest::for_all;

fn net(nodes: usize, gpus: usize) -> NetworkModel {
    let mut cfg = ClusterConfig::commodity(nodes);
    cfg.gpus_per_node = gpus;
    NetworkModel::new(cfg)
}

fn random_counts(g: &mut hetumoe::util::proptest::Gen, w: usize, max: usize) -> Vec<Vec<usize>> {
    (0..w).map(|_| (0..w).map(|_| g.usize_in(0..max)).collect()).collect()
}

#[test]
fn flat_timing_is_monotone_in_the_traffic_matrix() {
    for_all(48, |g| {
        let nodes = g.usize_in(1..4);
        let gpus = g.usize_in(1..4);
        let m = net(nodes, gpus);
        let w = nodes * gpus;
        let counts = random_counts(g, w, 32);
        let elem = 4 * g.usize_in(1..64);
        let base = alltoallv_timing(&m, &counts, elem).total;
        // Bump one random entry; the prediction must not decrease.
        let mut bumped = counts.clone();
        let s = g.usize_in(0..w);
        let d = g.usize_in(0..w);
        bumped[s][d] += g.usize_in(1..16);
        let after = alltoallv_timing(&m, &bumped, elem).total;
        assert!(
            after >= base - 1e-15,
            "flat: bumping ({s},{d}) lowered {base} to {after}"
        );
    });
}

#[test]
fn hierarchical_timing_is_monotone_in_the_traffic_matrix() {
    for_all(48, |g| {
        let nodes = g.usize_in(1..4);
        let gpus = g.usize_in(1..4);
        let m = net(nodes, gpus);
        let w = nodes * gpus;
        let counts = random_counts(g, w, 32);
        let elem = 4 * g.usize_in(1..64);
        let base = hierarchical_alltoallv_timing(&m, &counts, elem).total;
        let mut bumped = counts.clone();
        let s = g.usize_in(0..w);
        let d = g.usize_in(0..w);
        bumped[s][d] += g.usize_in(1..16);
        let after = hierarchical_alltoallv_timing(&m, &bumped, elem).total;
        assert!(
            after >= base - 1e-15,
            "hier: bumping ({s},{d}) lowered {base} to {after}"
        );
    });
}

#[test]
fn uniform_counts_reduce_to_equal_chunk_formulas() {
    for_all(32, |g| {
        let nodes = g.usize_in(1..5);
        let gpus = g.usize_in(1..5);
        let m = net(nodes, gpus);
        let w = nodes * gpus;
        let chunk = g.usize_in(1..512);
        let counts = vec![vec![chunk; w]; w];
        let flat_v = alltoallv_timing(&m, &counts, 4).total;
        let flat_eq = flat_alltoall_timing(&m, chunk * 4).total;
        assert!(
            (flat_v - flat_eq).abs() < 1e-12,
            "flat: {flat_v} vs equal-chunk {flat_eq} (n={nodes} g={gpus} c={chunk})"
        );
        let hier_v = hierarchical_alltoallv_timing(&m, &counts, 4).total;
        let hier_eq = hierarchical_alltoall_timing(&m, chunk * 4).total;
        assert!(
            (hier_v - hier_eq).abs() < 1e-12,
            "hier: {hier_v} vs equal-chunk {hier_eq} (n={nodes} g={gpus} c={chunk})"
        );
    });
}

#[test]
fn transpose_preserves_total_traffic_but_not_time() {
    // The combine/backward legs charge the transposed matrix; the
    // transpose moves the same bytes but may cost a very different
    // time (fan-in vs fan-out). Totals must stay monotone-consistent:
    // both directions are >= the empty matrix's cost.
    for_all(24, |g| {
        let m = net(2, g.usize_in(1..4));
        let w = m.cfg.world();
        let counts = random_counts(g, w, 24);
        let t_fwd = alltoallv_timing(&m, &counts, 64).total;
        let t_bwd = alltoallv_timing(&m, &transpose_counts(&counts), 64).total;
        let total: usize = counts.iter().flatten().sum();
        if total == 0 {
            assert_eq!(t_fwd, 0.0);
            assert_eq!(t_bwd, 0.0);
        } else {
            assert!(t_fwd >= 0.0 && t_bwd >= 0.0);
        }
        // Transposing twice is the identity on the prediction.
        let t_round =
            alltoallv_timing(&m, &transpose_counts(&transpose_counts(&counts)), 64).total;
        assert_eq!(t_fwd, t_round);
    });
}
