//! Property tests for the tracing layer (DESIGN.md §12): recording is
//! purely observational. A full train step (forward + backward) and a
//! full serving run produce **bit-identical** outputs, gradients and
//! report fields whether the recorder is on or off — and the trace the
//! enabled run captures is well-formed: spans nest on every lane and
//! every begin has an end.
//!
//! The recorder is process-global, so every test here serializes on one
//! mutex (the rest of the suite lives in other test binaries).

use std::sync::Mutex;

use hetumoe::backprop::TrainMoeLayer;
use hetumoe::config::{ClusterConfig, GateKind, MoeConfig};
use hetumoe::moe::{DispatchMode, MoeLayerOptions};
use hetumoe::obs::{trace, Trace, TraceRecorder};
use hetumoe::pipeline::{pipe_critical_path, OverlapTiming};
use hetumoe::serve::{ArrivalProcess, CommChoice, ServeConfig, ServeEngine};
use hetumoe::tensor::Tensor;
use hetumoe::util::rng::Rng;

static LOCK: Mutex<()> = Mutex::new(());

/// Everything a train step produces, flattened for exact comparison.
#[derive(PartialEq, Debug)]
struct TrainOutcome {
    outputs: Vec<f32>,
    dx: Vec<f32>,
    d_gate: Vec<f32>,
    d_experts: Vec<f32>,
    bytes_on_wire: usize,
    bytes_intra_node: usize,
    rows_deduped: usize,
    n_chunks: usize,
    comm_schedule: String,
    critical_path_bits: u64,
    comm_exposed_bits: u64,
    bwd_bytes_on_wire: usize,
    bwd_comm_schedule: String,
}

fn run_train_step(dispatch: DispatchMode) -> TrainOutcome {
    let cfg = MoeConfig {
        num_experts: 8,
        d_model: 16,
        ffn_hidden: 32,
        capacity_factor: 2.0,
        gate: GateKind::GShard,
    };
    let cluster = ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) };
    let opts = MoeLayerOptions { dispatch, ..Default::default() };
    let layer = TrainMoeLayer::native(cfg, cluster, opts, 11).unwrap();
    let mut rng = Rng::seed(5);
    let shards: Vec<Tensor> = (0..4).map(|_| Tensor::randn(&[24, 16], &mut rng)).collect();
    let dy: Vec<Tensor> = (0..4).map(|_| Tensor::randn(&[24, 16], &mut rng)).collect();
    let (outs, report, cache) = layer.forward_t(&shards, 0).unwrap();
    let (dx, grads, bwd) = layer.backward(&shards, &dy, &cache, 0.01).unwrap();
    TrainOutcome {
        outputs: outs.iter().flat_map(|t| t.data().to_vec()).collect(),
        dx: dx.iter().flat_map(|t| t.data().to_vec()).collect(),
        d_gate: grads.d_gate_weight.iter().flat_map(|t| t.data().to_vec()).collect(),
        d_experts: grads
            .experts
            .iter()
            .flat_map(|g| {
                g.dw1
                    .data()
                    .iter()
                    .chain(g.dw2.data())
                    .chain(&g.db1)
                    .chain(&g.db2)
                    .copied()
                    .collect::<Vec<f32>>()
            })
            .collect(),
        bytes_on_wire: report.bytes_on_wire,
        bytes_intra_node: report.bytes_intra_node,
        rows_deduped: report.rows_deduped,
        n_chunks: report.n_chunks,
        comm_schedule: report.comm_schedule.clone(),
        critical_path_bits: report.critical_path.to_bits(),
        comm_exposed_bits: report.comm_exposed.to_bits(),
        bwd_bytes_on_wire: bwd.bytes_on_wire,
        bwd_comm_schedule: bwd.comm_schedule.clone(),
    }
}

/// Run `f` with the recorder on, returning its result and the trace.
fn traced<T>(f: impl FnOnce() -> T) -> (T, Trace) {
    TraceRecorder::start();
    let out = f();
    (out, TraceRecorder::stop())
}

fn assert_well_formed(trace: &Trace) {
    assert!(!trace.events.is_empty(), "enabled run must capture spans");
    assert_eq!(trace::open_spans(), 0, "every span begin must have an end");
    if let Err(e) = trace.check_nesting() {
        panic!("spans must nest per lane: {e}");
    }
}

#[test]
fn train_step_is_bit_identical_with_tracing_on() {
    let _g = LOCK.lock().unwrap();
    for dispatch in [DispatchMode::Ragged, DispatchMode::Padded] {
        let off = run_train_step(dispatch);
        let (on, trace) = traced(|| run_train_step(dispatch));
        assert_eq!(off, on, "{dispatch:?}: tracing must not perturb the step");
        assert_well_formed(&trace);
        // The step emitted both halves of the taxonomy: measured spans
        // and the modeled overlap timeline.
        let names: Vec<&str> = trace.events.iter().map(|e| e.name.as_str()).collect();
        assert!(names.contains(&"step"));
        assert!(names.contains(&"bwd_step"));
        assert!(names.iter().any(|n| n.starts_with("dispatch.")));
        assert!(names.iter().any(|n| n.starts_with("bwd_dispatch.")));
        // And carries the wire accounting as span args.
        let step = trace.events.iter().find(|e| e.name == "step").unwrap();
        assert!(step.args.iter().any(|(k, _)| k == "bytes_on_wire"));
        assert!(step.args.iter().any(|(k, _)| k == "comm_schedule"));
    }
}

#[test]
fn serving_run_is_bit_identical_with_tracing_on() {
    let _g = LOCK.lock().unwrap();
    let cfg = ServeConfig {
        moe: MoeConfig {
            num_experts: 8,
            d_model: 32,
            ffn_hidden: 64,
            capacity_factor: 1.25,
            gate: GateKind::Switch,
        },
        cluster: ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) },
        process: ArrivalProcess::Poisson { rate: 500.0 },
        comm: CommChoice::Auto,
        duration: 0.2,
        seed: 7,
        ..ServeConfig::default_run()
    };
    let run = |cfg: ServeConfig| {
        let mut engine = ServeEngine::new(cfg).unwrap();
        engine.run().unwrap()
    };
    let off = run(cfg.clone());
    let (on, trace) = traced(|| run(cfg));
    assert_eq!(off.offered, on.offered);
    assert_eq!(off.completed, on.completed);
    assert_eq!(off.dropped, on.dropped);
    assert_eq!(off.latency.p50.to_bits(), on.latency.p50.to_bits());
    assert_eq!(off.latency.p99.to_bits(), on.latency.p99.to_bits());
    assert_eq!(off.latency_window.p99.to_bits(), on.latency_window.p99.to_bits());
    assert_eq!(off.goodput_tps.to_bits(), on.goodput_tps.to_bits());
    assert_eq!(off.breakdown.critical_path.to_bits(), on.breakdown.critical_path.to_bits());
    assert_well_formed(&trace);
    // Serving is analytic: every batch lands on the modeled timeline.
    let names: Vec<&str> = trace.events.iter().map(|e| e.name.as_str()).collect();
    assert!(names.contains(&"gate"));
    assert!(names.contains(&"exchange"));
    assert!(names.contains(&"reverse_layout"));
}

#[test]
fn stopping_discards_spans_but_keeps_balance() {
    let _g = LOCK.lock().unwrap();
    TraceRecorder::start();
    let span = trace::span("outer");
    let trace = TraceRecorder::stop();
    // The guard outlived the recorder: its event is discarded, but the
    // open-span balance still returns to zero.
    drop(span);
    assert_eq!(trace::open_spans(), 0);
    assert!(trace.events.is_empty());
    // Disabled emission is a no-op.
    let inert = trace::span("ignored");
    drop(inert);
    assert!(!trace::enabled());
}

#[test]
fn recorder_exports_chrome_trace() {
    let _g = LOCK.lock().unwrap();
    TraceRecorder::start();
    {
        let mut outer = trace::span("outer");
        outer.arg("bytes_on_wire", 4096usize);
        outer.arg("schedule", "hier");
        {
            let _inner = trace::span("inner");
        }
    }
    let w0 = trace::model_window(1.0);
    trace::model_event(trace::ModelLane::Net, "m0", w0, 0.5, Vec::new());
    let w1 = trace::model_window(2.0);
    assert!((w1 - w0 - 1.0).abs() < 1e-12, "windows are consecutive");
    trace::model_event(trace::ModelLane::Expert, "m1", w1, 2.0, Vec::new());
    assert_eq!(trace::open_spans(), 0);
    let tr = TraceRecorder::stop();
    assert!(!trace::enabled());
    assert_eq!(tr.events.len(), 4);
    tr.check_nesting().unwrap();
    // Measured lanes re-based to zero.
    let outer = tr.events.iter().find(|e| e.name == "outer").unwrap();
    assert_eq!(outer.pid, trace::PID_MEASURED);
    assert!(outer.ts.abs() < 1e-9);
    let inner = tr.events.iter().find(|e| e.name == "inner").unwrap();
    assert!(inner.ts >= outer.ts && inner.ts + inner.dur <= outer.ts + outer.dur + 1e-9);
    let j = tr.to_chrome_json();
    let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
    // 4 spans + 2 process metas + 3 lane metas (host, net, expert).
    assert_eq!(evs.len(), 9);
    assert_eq!(j.str_field("displayTimeUnit").unwrap(), "ms");
    let x = evs
        .iter()
        .find(|e| e.str_field("name").map(|n| n == "outer").unwrap_or(false))
        .unwrap();
    assert_eq!(x.str_field("ph").unwrap(), "X");
    let args = x.get("args").unwrap();
    assert_eq!(args.f64_field("bytes_on_wire").unwrap(), 4096.0);
    assert_eq!(args.str_field("schedule").unwrap(), "hier");
}

#[test]
fn model_overlap_emits_contained_chunks() {
    let _g = LOCK.lock().unwrap();
    TraceRecorder::start();
    let o = OverlapTiming {
        dispatch: vec![0.1, 0.2],
        compute: vec![0.3, 0.1],
        combine: vec![0.05, 0.1],
        critical_path: 0.0,
    };
    let o = OverlapTiming {
        critical_path: pipe_critical_path(&o.dispatch, &o.compute, &o.combine),
        ..o
    };
    let at = trace::model_window(o.critical_path);
    trace::model_overlap(at, "fwd_", &o, vec![("rows_deduped".into(), 7usize.into())]);
    let tr = TraceRecorder::stop();
    tr.check_nesting().unwrap();
    // 1 container + 2 chunks × 3 legs.
    assert_eq!(tr.events.len(), 7);
    let region = tr.events.iter().find(|e| e.name == "fwd_exchange").unwrap();
    assert!((region.dur - o.critical_path).abs() < 1e-12);
    for e in &tr.events {
        if e.pid == trace::PID_MODELED && e.name != "fwd_exchange" {
            assert!(e.ts >= region.ts - 1e-12);
            assert!(e.ts + e.dur <= region.ts + region.dur + 1e-9);
        }
    }
}
