//! Wire-precision integration tests: `--wire f32` is bit-identical to
//! the pre-PR default everywhere, bf16/f16 payload legs keep forward
//! outputs and gradients within the encoding's tolerance of the f32
//! run across flat/hier × dedup × chunking, the byte bill exactly
//! halves, and a full seeded training run converges to the same place.

use hetumoe::backprop::{smoothed_losses, NativeTrainer, TrainMoeLayer, TrainRunConfig};
use hetumoe::comm::schedule::CommChoice;
use hetumoe::comm::WirePrecision;
use hetumoe::config::{ClusterConfig, GateKind, MoeConfig};
use hetumoe::moe::{DispatchMode, MoeLayerOptions};
use hetumoe::pipeline::ChunkChoice;
use hetumoe::tensor::Tensor;
use hetumoe::util::rng::Rng;

fn small_cluster() -> ClusterConfig {
    ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) }
}

fn small_moe(gate: GateKind) -> MoeConfig {
    MoeConfig { num_experts: 4, d_model: 16, ffn_hidden: 32, capacity_factor: 2.0, gate }
}

fn layer(opts: MoeLayerOptions, seed: u64) -> TrainMoeLayer {
    TrainMoeLayer::native(small_moe(GateKind::TopK { k: 2 }), small_cluster(), opts, seed)
        .unwrap()
}

fn batch(seed: u64, tokens: usize, d: usize) -> (Vec<Tensor>, Vec<Tensor>) {
    let mut rng = Rng::seed(seed);
    let shards = (0..4).map(|_| Tensor::randn(&[tokens, d], &mut rng)).collect();
    let dy = (0..4).map(|_| Tensor::randn(&[tokens, d], &mut rng)).collect();
    (shards, dy)
}

fn max_abs(ts: &[Tensor]) -> f32 {
    ts.iter().flat_map(|t| t.data().iter()).fold(0.0f32, |m, &v| m.max(v.abs()))
}

fn max_diff(a: &[Tensor], b: &[Tensor]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x.max_abs_diff(y)).fold(0.0f32, f32::max)
}

/// An explicit `--wire f32` run is bit-identical to the default option
/// set — outputs, gradients, and every byte counter. The compressed
/// encodings are strictly pay-to-play.
#[test]
fn f32_wire_bit_identical_to_default() {
    for alltoall in [CommChoice::Flat, CommChoice::Hierarchical] {
        let base = layer(MoeLayerOptions { alltoall, ..Default::default() }, 11);
        let wired = layer(
            MoeLayerOptions { alltoall, wire: WirePrecision::F32, ..Default::default() },
            11,
        );
        let (shards, dy) = batch(21, 24, 16);
        let (bo, brep, bc) = base.forward_t(&shards, 0).unwrap();
        let (wo, wrep, wc) = wired.forward_t(&shards, 0).unwrap();
        for (x, y) in bo.iter().zip(&wo) {
            assert!(x.allclose(y, 0.0), "f32 wire changed forward outputs");
        }
        assert_eq!(brep.bytes_on_wire, wrep.bytes_on_wire);
        assert_eq!(brep.bytes_intra_node, wrep.bytes_intra_node);
        assert_eq!(brep.rows_deduped, wrep.rows_deduped);
        let (bdx, bg, _) = base.backward(&shards, &dy, &bc, 0.01).unwrap();
        let (wdx, wg, _) = wired.backward(&shards, &dy, &wc, 0.01).unwrap();
        for (x, y) in bdx.iter().zip(&wdx) {
            assert!(x.allclose(y, 0.0), "f32 wire changed dx");
        }
        for (x, y) in bg.d_gate_weight.iter().zip(&wg.d_gate_weight) {
            assert!(x.allclose(y, 0.0), "f32 wire changed d_gate_weight");
        }
        for (x, y) in bg.experts.iter().zip(&wg.experts) {
            assert!(x.dw1.allclose(&y.dw1, 0.0), "f32 wire changed dw1");
            assert!(x.dw2.allclose(&y.dw2, 0.0), "f32 wire changed dw2");
        }
    }
}

/// Compressed forward outputs track the f32 run within the encoding's
/// tolerance across schedule × dedup × chunking, quantization actually
/// happens, and chunking never changes numerics.
#[test]
fn compressed_forward_within_tolerance_across_configs() {
    let (shards, _) = batch(22, 24, 16);
    // f32 references per schedule (dedup/chunking are numerics-neutral,
    // asserted by the existing equivalence suites).
    let f32_ref = |alltoall| {
        let (o, _, _) = layer(MoeLayerOptions { alltoall, ..Default::default() }, 11)
            .forward_t(&shards, 0)
            .unwrap();
        o
    };
    let ref_flat = f32_ref(CommChoice::Flat);
    let scale = max_abs(&ref_flat).max(1.0);
    for (wire, tol) in [(WirePrecision::Bf16, 0.10f32), (WirePrecision::F16, 0.03)] {
        for alltoall in [CommChoice::Flat, CommChoice::Hierarchical] {
            for dedup in [false, true] {
                let mut unchunked: Option<Vec<Tensor>> = None;
                for chunks in [ChunkChoice::Fixed(1), ChunkChoice::Auto] {
                    let l = layer(
                        MoeLayerOptions { alltoall, dedup, chunks, wire, ..Default::default() },
                        11,
                    );
                    let (o, rep, _) = l.forward_t(&shards, 0).unwrap();
                    let d = max_diff(&ref_flat, &o);
                    assert!(
                        d <= tol * scale,
                        "{} {}/dedup={dedup}: drift {d} exceeds {tol}*{scale}",
                        wire.name(),
                        alltoall.name(),
                    );
                    assert!(d > 0.0, "{} must actually quantize", wire.name());
                    assert_eq!(rep.wire, wire.name(), "report must carry the wire format");
                    // Chunking is an overlap decision, never a numerics
                    // decision — also under compressed wire.
                    match &unchunked {
                        None => unchunked = Some(o),
                        Some(u) => {
                            for (x, y) in u.iter().zip(&o) {
                                assert!(x.allclose(y, 0.0), "chunking changed outputs");
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Quantization happens uniformly at exchange entry, so flat and
/// hierarchical forwards agree bitwise at every precision (dedup off:
/// the payload legs are byte-for-byte the same rows).
#[test]
fn flat_and_hier_forward_bitwise_equal_per_precision() {
    let (shards, _) = batch(23, 24, 16);
    for wire in [WirePrecision::F32, WirePrecision::Bf16, WirePrecision::F16] {
        let mk = |alltoall| {
            layer(MoeLayerOptions { alltoall, dedup: false, wire, ..Default::default() }, 11)
                .forward_t(&shards, 0)
                .unwrap()
                .0
        };
        let fo = mk(CommChoice::Flat);
        let ho = mk(CommChoice::Hierarchical);
        for (x, y) in fo.iter().zip(&ho) {
            assert!(x.allclose(y, 0.0), "{}: flat/hier diverged", wire.name());
        }
    }
}

/// Compressed gradients track the f32 gradients within tolerance:
/// gradient rows cross the wire quantized, accumulation stays f32.
#[test]
fn compressed_backward_within_tolerance() {
    let (shards, dy) = batch(24, 24, 16);
    let reference = layer(MoeLayerOptions::default(), 11);
    let (_, _, rc) = reference.forward_t(&shards, 0).unwrap();
    let (rdx, rg, _) = reference.backward(&shards, &dy, &rc, 0.01).unwrap();
    let dx_scale = max_abs(&rdx).max(1.0);
    let gw_scale = max_abs(&rg.d_gate_weight).max(1.0);
    for (wire, tol) in [(WirePrecision::Bf16, 0.2f32), (WirePrecision::F16, 0.05)] {
        for alltoall in [CommChoice::Flat, CommChoice::Hierarchical] {
            for dedup in [false, true] {
                let l = layer(
                    MoeLayerOptions { alltoall, dedup, wire, ..Default::default() },
                    11,
                );
                let (_, _, c) = l.forward_t(&shards, 0).unwrap();
                let (dx, g, _) = l.backward(&shards, &dy, &c, 0.01).unwrap();
                let ddx = max_diff(&rdx, &dx);
                assert!(
                    ddx <= tol * dx_scale,
                    "{} {}/dedup={dedup}: dx drift {ddx} vs scale {dx_scale}",
                    wire.name(),
                    alltoall.name(),
                );
                let dgw = max_diff(&rg.d_gate_weight, &g.d_gate_weight);
                assert!(
                    dgw <= tol * gw_scale,
                    "{} {}/dedup={dedup}: d_gate_weight drift {dgw} vs scale {gw_scale}",
                    wire.name(),
                    alltoall.name(),
                );
                for (a, b) in rg.experts.iter().zip(&g.experts) {
                    let s = a.dw1.data().iter().fold(1.0f32, |m, v| m.max(v.abs()));
                    assert!(a.dw1.max_abs_diff(&b.dw1) <= tol * s, "dw1 drift");
                }
            }
        }
    }
}

/// bf16 exactly halves the forward byte bill at the layer level (flat,
/// no dedup: the bill is purely payload rows × row_bytes).
#[test]
fn bf16_exactly_halves_layer_bytes() {
    let (shards, _) = batch(25, 24, 16);
    let rep_of = |wire| {
        layer(
            MoeLayerOptions {
                alltoall: CommChoice::Flat,
                dedup: false,
                wire,
                ..Default::default()
            },
            11,
        )
        .forward_t(&shards, 0)
        .unwrap()
        .1
    };
    let r32 = rep_of(WirePrecision::F32);
    let rbf = rep_of(WirePrecision::Bf16);
    let rhf = rep_of(WirePrecision::F16);
    assert!(r32.bytes_on_wire > 0);
    assert_eq!(r32.bytes_on_wire, 2 * rbf.bytes_on_wire);
    assert_eq!(r32.bytes_intra_node, 2 * rbf.bytes_intra_node);
    assert_eq!(rbf.bytes_on_wire, rhf.bytes_on_wire);
}

/// The compressed wire requires the ragged data path: padded dispatch
/// has no quantization boundary and must refuse loudly, not silently
/// bill the wrong bytes.
#[test]
fn padded_dispatch_rejects_compressed_wire() {
    let l = layer(
        MoeLayerOptions {
            dispatch: DispatchMode::Padded,
            wire: WirePrecision::Bf16,
            ..Default::default()
        },
        11,
    );
    let (shards, _) = batch(26, 16, 16);
    assert!(l.forward_t(&shards, 0).is_err(), "padded + bf16 must be a config error");
}

fn train_cfg(wire: WirePrecision) -> TrainRunConfig {
    TrainRunConfig {
        moe: small_moe(GateKind::Switch),
        cluster: small_cluster(),
        opts: MoeLayerOptions { wire, ..Default::default() },
        steps: 220,
        tokens_per_rank: 32,
        num_classes: 4,
        lr: 3e-3,
        aux_coef: 1e-2,
        noise: 0.3,
        seed: 0,
        log_every: 0,
        faults: hetumoe::fault::FaultPlan::none(),
        ckpt_every: 0,
        ckpt_dir: None,
        ..TrainRunConfig::default_run()
    }
}

/// The end-to-end guarantee: a 200+-step seeded run over the bf16 wire
/// still converges — smoothed loss strictly decreases across the same
/// checkpoints as the f32 curve and lands within tolerance of it — and
/// the whole-run byte bill (fwd and bwd) is exactly half.
#[test]
fn bf16_training_converges_like_f32_at_half_the_bytes() {
    let mut t32 = NativeTrainer::new(train_cfg(WirePrecision::F32)).unwrap();
    let s32 = t32.run().unwrap();
    let mut tbf = NativeTrainer::new(train_cfg(WirePrecision::Bf16)).unwrap();
    let sbf = tbf.run().unwrap();

    let smooth32 = smoothed_losses(&t32.losses(), 0.1);
    let smoothbf = smoothed_losses(&tbf.losses(), 0.1);
    for w in [20usize, 70, 120, 170, 219].windows(2) {
        assert!(
            smoothbf[w[1]] < smoothbf[w[0]],
            "bf16 smoothed loss must strictly decrease: {} vs {}",
            smoothbf[w[0]],
            smoothbf[w[1]]
        );
    }
    let (f32_final, bf_final) = (smooth32[219], smoothbf[219]);
    assert!(
        (bf_final - f32_final).abs() <= 0.25 * f32_final.abs().max(0.1),
        "bf16 final smoothed loss {bf_final} strays from f32's {f32_final}"
    );

    // Whole-run mean byte counters: exactly half on both directions.
    let (b32, bbf) = (s32.breakdown, sbf.breakdown);
    assert!(b32.bytes_on_wire > 0.0 && b32.bytes_on_wire_bwd > 0.0);
    assert!((b32.bytes_on_wire - 2.0 * bbf.bytes_on_wire).abs() < 1e-6 * b32.bytes_on_wire);
    assert!(
        (b32.bytes_on_wire_bwd - 2.0 * bbf.bytes_on_wire_bwd).abs()
            < 1e-6 * b32.bytes_on_wire_bwd
    );
    assert_eq!(bbf.wire, "bf16");
}
