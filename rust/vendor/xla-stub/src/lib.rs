//! API-surface **stub** of the `xla` (PJRT bindings) crate.
//!
//! The offline build environment does not vendor the real XLA toolchain,
//! but the `hetumoe` crate's `pjrt` feature must keep compiling so the
//! artifact-execution path can be exercised wherever the toolchain *is*
//! present. This crate declares exactly the types and method signatures
//! `hetumoe` uses; every entry point that would touch PJRT returns
//! [`Error::Unavailable`] at runtime. To run real artifacts, point the
//! `xla` path dependency in `rust/Cargo.toml` at a genuine checkout —
//! no `hetumoe` source changes are needed.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type mirroring the real crate's.
#[derive(Debug)]
pub enum Error {
    /// The stub is compiled in; the real PJRT runtime is absent.
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: PJRT unavailable (the offline `xla` stub is linked; \
                 point rust/Cargo.toml's `xla` path at a real checkout)"
            ),
        }
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// Element types transferable to/from device buffers.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Host-side literal value (stub: carries no data).
#[derive(Debug, Default)]
pub struct Literal {}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal {}
    }

    /// Reshape to `dims`.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    /// Flatten a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    /// Copy out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    /// First element of the literal.
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        unavailable("Literal::get_first_element")
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto {}

impl HloModuleProto {
    /// Parse HLO text from a file.
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation handle (stub).
#[derive(Debug)]
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

/// Device buffer handle (stub).
#[derive(Debug)]
pub struct PjRtBuffer {}

impl PjRtBuffer {
    /// Download the buffer as a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    client: PjRtClient,
}

impl PjRtLoadedExecutable {
    /// The client that compiled this executable.
    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    /// Execute with host literals.
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }

    /// Execute with borrowed device buffers.
    pub fn execute_b<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// PJRT client handle (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient {}

impl PjRtClient {
    /// Create a CPU client.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    /// Compile a computation.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    /// Upload a host buffer to the device.
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("PJRT unavailable"), "{msg}");
    }
}
