//! The analysis engine: source loading, comment/string stripping,
//! test-region masking, diagnostics, allowlists and the report.
//!
//! The lints are line/token-level heuristics, not a full parser — the
//! repo's rustfmt-normalized style makes that reliable, and anything a
//! heuristic cannot see is handled by the allowlist (see DESIGN.md §16
//! for the policy). Every structure here is deterministic: files are
//! walked in sorted order and diagnostics are sorted before emission.

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use crate::lints::{self, Lint};
use crate::schema;

/// One finding, anchored to a repo-relative path and a 1-based line.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub lint: Lint,
    pub path: String,
    pub line: usize,
    pub snippet: String,
    pub message: String,
}

impl Diagnostic {
    pub fn new(lint: Lint, path: &str, line: usize, snippet: &str, message: String) -> Self {
        Diagnostic {
            lint,
            path: path.to_string(),
            line,
            snippet: snippet.trim().chars().take(120).collect(),
            message,
        }
    }
}

/// One allowlist entry: `LNNN <path-suffix> <line-substring…>`.
#[derive(Clone, Debug)]
pub struct AllowEntry {
    pub lint: Lint,
    pub path: String,
    pub pattern: String,
    pub file_line: usize,
    pub used: bool,
}

/// Everything one `analysis` run produced.
pub struct Report {
    pub violations: Vec<Diagnostic>,
    pub allowed: Vec<Diagnostic>,
    pub unused_allow: Vec<String>,
    pub files_scanned: usize,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.violations.is_empty() && self.unused_allow.is_empty()
    }

    /// Machine-readable report (std-only, hand-rolled escaping).
    pub fn to_json(&self) -> String {
        let diag = |d: &Diagnostic| {
            format!(
                "{{\"lint\":{},\"name\":{},\"path\":{},\"line\":{},\"snippet\":{},\"message\":{}}}",
                json_str(d.lint.id()),
                json_str(d.lint.name()),
                json_str(&d.path),
                d.line,
                json_str(&d.snippet),
                json_str(&d.message)
            )
        };
        let violations: Vec<String> = self.violations.iter().map(diag).collect();
        let allowed: Vec<String> = self.allowed.iter().map(diag).collect();
        let unused: Vec<String> = self.unused_allow.iter().map(|s| json_str(s)).collect();
        format!(
            "{{\"files_scanned\":{},\"clean\":{},\"violations\":[{}],\"allowed\":[{}],\"unused_allow\":[{}]}}",
            self.files_scanned,
            self.clean(),
            violations.join(","),
            allowed.join(","),
            unused.join(",")
        )
    }

    /// Human-readable report; with `fix_hints` each lint's remediation
    /// guidance is printed once under its first finding.
    pub fn print_human(&self, fix_hints: bool) {
        let mut hinted: Vec<&str> = Vec::new();
        for d in &self.violations {
            println!("{} [{} {}] {}", loc(d), d.lint.id(), d.lint.name(), d.message);
            if !d.snippet.is_empty() {
                println!("    > {}", d.snippet);
            }
            if fix_hints && !hinted.contains(&d.lint.id()) {
                hinted.push(d.lint.id());
                println!("    fix: {}", d.lint.hint());
            }
        }
        for u in &self.unused_allow {
            println!("unused allowlist entry (remove it): {u}");
        }
        if self.clean() {
            println!(
                "analysis: clean — {} files scanned, {} allowed suppressions",
                self.files_scanned,
                self.allowed.len()
            );
        } else {
            println!(
                "analysis: {} violation(s), {} unused allowlist entr(ies) across {} files",
                self.violations.len(),
                self.unused_allow.len(),
                self.files_scanned
            );
            if !fix_hints {
                println!("(re-run with --fix-hints for remediation guidance)");
            }
        }
    }
}

fn loc(d: &Diagnostic) -> String {
    format!("{}:{}", d.path, d.line)
}

/// JSON string escaping for the hand-rolled emitter above.
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// A source file with its comment/string-stripped shadow and test mask.
pub struct SourceFile {
    /// Repo-relative path with forward slashes.
    pub rel: String,
    /// Verbatim lines.
    pub raw: Vec<String>,
    /// Same lines with comments and string/char literal contents
    /// blanked to spaces — token searches run on these.
    pub code: Vec<String>,
    /// `true` for lines inside a `#[cfg(test)]` region.
    pub test: Vec<bool>,
}

impl SourceFile {
    pub fn parse(rel: &str, text: &str) -> SourceFile {
        let raw: Vec<String> = text.lines().map(str::to_string).collect();
        let code: Vec<String> = strip_comments_and_strings(text)
            .lines()
            .map(str::to_string)
            .collect();
        debug_assert_eq!(raw.len(), code.len());
        let test = test_mask(&code);
        SourceFile { rel: rel.to_string(), raw, code, test }
    }

    /// Joined code text of lines `from..from+span` (for statements that
    /// wrap across lines), capped at the file end.
    pub fn window(&self, from: usize, span: usize) -> String {
        let hi = (from + span).min(self.code.len());
        self.code[from..hi].join("\n")
    }
}

/// Run the full pass over `root` (the repo root). `allow` may not exist,
/// in which case the allowlist is empty.
pub fn run(root: &Path, allow: &Path) -> io::Result<Report> {
    let mut entries = load_allowlist(allow)?;
    let files = walk_sources(&root.join("rust").join("src"))?;
    let mut all: Vec<Diagnostic> = Vec::new();
    let mut sources: Vec<SourceFile> = Vec::new();
    for path in &files {
        let text = fs::read_to_string(path)?;
        let rel = rel_path(root, path);
        sources.push(SourceFile::parse(&rel, &text));
    }
    for sf in &sources {
        all.extend(lints::check_file(sf));
    }
    all.extend(schema::check(root));
    all.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.lint.id()).cmp(&(b.path.as_str(), b.line, b.lint.id()))
    });

    let mut violations = Vec::new();
    let mut allowed = Vec::new();
    for d in all {
        if inline_allowed(&sources, &d) || list_allowed(&mut entries, &sources, &d) {
            allowed.push(d);
        } else {
            violations.push(d);
        }
    }
    let unused_allow = entries
        .iter()
        .filter(|e| !e.used)
        .map(|e| format!("{}:{} {} {} {}", allow.display(), e.file_line, e.lint.id(), e.path, e.pattern))
        .collect();
    Ok(Report { violations, allowed, unused_allow, files_scanned: sources.len() })
}

/// `lint:allow(LNNN…)` on the flagged line or the line above it.
fn inline_allowed(sources: &[SourceFile], d: &Diagnostic) -> bool {
    let Some(sf) = sources.iter().find(|s| s.rel == d.path) else {
        return false;
    };
    let check = |line1: usize| -> bool {
        if line1 == 0 || line1 > sf.raw.len() {
            return false;
        }
        let raw = &sf.raw[line1 - 1];
        match raw.find("lint:allow(") {
            Some(pos) => {
                let rest = &raw[pos + "lint:allow(".len()..];
                let inside = rest.split(')').next().unwrap_or("");
                inside.split(',').any(|id| id.trim() == d.lint.id())
            }
            None => false,
        }
    };
    check(d.line) || check(d.line.saturating_sub(1))
}

/// Match against the allowlist file, marking entries used.
fn list_allowed(entries: &mut [AllowEntry], sources: &[SourceFile], d: &Diagnostic) -> bool {
    let raw_line = sources
        .iter()
        .find(|s| s.rel == d.path)
        .and_then(|s| s.raw.get(d.line.saturating_sub(1)))
        .map(String::as_str)
        .unwrap_or("");
    let mut hit = false;
    for e in entries.iter_mut() {
        if e.lint == d.lint && d.path.ends_with(&e.path) && raw_line.contains(&e.pattern) {
            e.used = true;
            hit = true;
        }
    }
    hit
}

/// Parse the allowlist: `LNNN <path-suffix> <line-substring…>` per line,
/// `#` comments and blanks skipped.
pub fn load_allowlist(path: &Path) -> io::Result<Vec<AllowEntry>> {
    let text = match fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(e),
    };
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut rest = line;
        let lint_tok = take_token(&mut rest);
        let path_tok = take_token(&mut rest);
        let pattern = rest.trim().to_string();
        let lint = match Lint::from_id(&lint_tok) {
            Some(l) => l,
            None => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("{}:{}: unknown lint id '{}'", path.display(), i + 1, lint_tok),
                ));
            }
        };
        if path_tok.is_empty() || pattern.is_empty() {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!(
                    "{}:{}: expected `LNNN <path-suffix> <line-substring>`",
                    path.display(),
                    i + 1
                ),
            ));
        }
        out.push(AllowEntry { lint, path: path_tok, pattern, file_line: i + 1, used: false });
    }
    Ok(out)
}

fn take_token(rest: &mut &str) -> String {
    let s = rest.trim_start();
    let end = s.find(char::is_whitespace).unwrap_or(s.len());
    let tok = s[..end].to_string();
    *rest = &s[end..];
    tok
}

/// All `.rs` files under `dir`, recursively, sorted for determinism.
pub fn walk_sources(dir: &Path) -> io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        let rd = match fs::read_dir(&d) {
            Ok(rd) => rd,
            Err(e) if e.kind() == io::ErrorKind::NotFound => continue,
            Err(e) => return Err(e),
        };
        for entry in rd {
            let entry = entry?;
            let p = entry.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|e| e == "rs") {
                out.push(p);
            }
        }
    }
    out.sort();
    Ok(out)
}

fn rel_path(root: &Path, path: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect::<Vec<_>>()
        .join("/")
}

/// Blank comments and string/char-literal contents to spaces, keeping
/// line structure so line numbers and columns survive.
pub fn strip_comments_and_strings(text: &str) -> String {
    #[derive(Clone, Copy, PartialEq)]
    enum St {
        Code,
        LineComment,
        BlockComment,
        Str,
        RawStr,
        CharLit,
    }
    let b: Vec<char> = text.chars().collect();
    let n = b.len();
    let mut out: Vec<char> = Vec::with_capacity(n);
    let mut st = St::Code;
    let mut block_depth = 0usize;
    let mut raw_hashes = 0usize;
    let mut i = 0usize;
    let blank = |c: char| if c == '\n' { '\n' } else { ' ' };
    while i < n {
        let c = b[i];
        match st {
            St::Code => {
                if c == '/' && i + 1 < n && b[i + 1] == '/' {
                    st = St::LineComment;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else if c == '/' && i + 1 < n && b[i + 1] == '*' {
                    st = St::BlockComment;
                    block_depth = 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else if c == '"' {
                    st = St::Str;
                    out.push(' ');
                    i += 1;
                } else if c == 'r' && i + 1 < n && (b[i + 1] == '"' || b[i + 1] == '#') {
                    // Raw string r"…" / r#"…"# (not `r#ident`).
                    let mut j = i + 1;
                    let mut hashes = 0usize;
                    while j < n && b[j] == '#' {
                        hashes += 1;
                        j += 1;
                    }
                    if j < n && b[j] == '"' {
                        st = St::RawStr;
                        raw_hashes = hashes;
                        for _ in i..=j {
                            out.push(' ');
                        }
                        i = j + 1;
                    } else {
                        out.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // Char literal vs lifetime: a literal is '<esc>' or
                    // 'x' — a lifetime quote is never closed by a quote
                    // two chars later.
                    if i + 1 < n && b[i + 1] == '\\' {
                        st = St::CharLit;
                        out.push(' ');
                        i += 1;
                    } else if i + 2 < n && b[i + 2] == '\'' {
                        st = St::CharLit;
                        out.push(' ');
                        i += 1;
                    } else {
                        out.push(c);
                        i += 1;
                    }
                } else {
                    out.push(c);
                    i += 1;
                }
            }
            St::LineComment => {
                if c == '\n' {
                    st = St::Code;
                    out.push('\n');
                } else {
                    out.push(' ');
                }
                i += 1;
            }
            St::BlockComment => {
                if c == '*' && i + 1 < n && b[i + 1] == '/' {
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                    block_depth -= 1;
                    if block_depth == 0 {
                        st = St::Code;
                    }
                } else if c == '/' && i + 1 < n && b[i + 1] == '*' {
                    block_depth += 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else {
                    out.push(blank(c));
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' && i + 1 < n {
                    out.push(' ');
                    out.push(if b[i + 1] == '\n' { '\n' } else { ' ' });
                    i += 2;
                } else if c == '"' {
                    st = St::Code;
                    out.push(' ');
                    i += 1;
                } else {
                    out.push(blank(c));
                    i += 1;
                }
            }
            St::RawStr => {
                if c == '"' {
                    let mut j = i + 1;
                    let mut k = 0usize;
                    while j < n && k < raw_hashes && b[j] == '#' {
                        k += 1;
                        j += 1;
                    }
                    if k == raw_hashes {
                        for _ in i..j {
                            out.push(' ');
                        }
                        i = j;
                        st = St::Code;
                    } else {
                        out.push(' ');
                        i += 1;
                    }
                } else {
                    out.push(blank(c));
                    i += 1;
                }
            }
            St::CharLit => {
                if c == '\\' && i + 1 < n {
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else if c == '\'' {
                    st = St::Code;
                    out.push(' ');
                    i += 1;
                } else {
                    out.push(' ');
                    i += 1;
                }
            }
        }
    }
    out.into_iter().collect()
}

/// Mark every line inside a `#[cfg(test)]`-attributed item (in this
/// repo: the per-module `mod tests { … }` blocks). The attribute line
/// itself is marked too.
fn test_mask(code: &[String]) -> Vec<bool> {
    let n = code.len();
    let mut mask = vec![false; n];
    let mut i = 0usize;
    while i < n {
        if !code[i].contains("#[cfg(test)]") {
            i += 1;
            continue;
        }
        let mut depth = 0i64;
        let mut opened = false;
        let mut j = i;
        while j < n {
            for ch in code[j].chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            mask[j] = true;
            if opened && depth <= 0 {
                break;
            }
            // Attribute on a braceless item (`#[cfg(test)] use …;`):
            // stop at the terminating semicolon instead of running away.
            if !opened && j > i && code[j].trim_end().ends_with(';') {
                break;
            }
            j += 1;
        }
        i = j + 1;
    }
    mask
}

/// Find `needle` in `hay` at a token boundary (chars on both sides are
/// not identifier chars). Returns byte offsets of every occurrence.
pub fn token_positions(hay: &str, needle: &str) -> Vec<usize> {
    let ident = |c: char| c.is_ascii_alphanumeric() || c == '_';
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(pos) = hay[from..].find(needle) {
        let at = from + pos;
        let before_ok = hay[..at].chars().next_back().map_or(true, |c| !ident(c));
        let after_ok = hay[at + needle.len()..].chars().next().map_or(true, |c| !ident(c));
        if before_ok && after_ok {
            out.push(at);
        }
        from = at + needle.len().max(1);
    }
    out
}
