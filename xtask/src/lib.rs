//! Library surface of the `xtask` developer-task crate, exposed so the
//! integration tests in `xtask/tests/` can drive the analysis engine
//! against fixture trees. The `xtask` binary is a thin CLI over this.

pub mod engine;
pub mod lints;
pub mod schema;
