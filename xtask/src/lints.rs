//! The lint catalogue: repo-specific invariants checked line/token-wise.
//!
//! Each lint documents its scope (which modules it restricts) and its
//! rationale; DESIGN.md §16 carries the narrative version. Scopes are
//! path prefixes relative to the repo root, so fixture trees in
//! `xtask/tests/` can mirror the layout.

use crate::engine::{token_positions, Diagnostic, SourceFile};

/// Lint identifiers. `L004` (schema pinning) is implemented in
/// [`crate::schema`]; everything else lives here.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Lint {
    L001,
    L002,
    L003,
    L004,
    L005,
    L006,
    L007,
}

impl Lint {
    pub fn all() -> [Lint; 7] {
        [Lint::L001, Lint::L002, Lint::L003, Lint::L004, Lint::L005, Lint::L006, Lint::L007]
    }

    pub fn id(self) -> &'static str {
        match self {
            Lint::L001 => "L001",
            Lint::L002 => "L002",
            Lint::L003 => "L003",
            Lint::L004 => "L004",
            Lint::L005 => "L005",
            Lint::L006 => "L006",
            Lint::L007 => "L007",
        }
    }

    pub fn from_id(id: &str) -> Option<Lint> {
        Lint::all().into_iter().find(|l| l.id() == id)
    }

    pub fn name(self) -> &'static str {
        match self {
            Lint::L001 => "nan-ordering",
            Lint::L002 => "byte-literal",
            Lint::L003 => "nondeterministic-iteration",
            Lint::L004 => "schema-pinning",
            Lint::L005 => "unwrap-in-cli",
            Lint::L006 => "span-balance",
            Lint::L007 => "wall-clock-ban",
        }
    }

    /// The `--fix-hints` suggestion.
    pub fn hint(self) -> &'static str {
        match self {
            Lint::L001 => {
                "order floats with `total_cmp` (or `partial_cmp(..).unwrap_or(Ordering::..)` \
                 when a NaN policy is intended) — `partial_cmp().unwrap()` panics on NaN"
            }
            Lint::L002 => {
                "route element sizes through `comm::precision::F32_BYTES` / `F32_BYTES_F` or \
                 `WirePrecision::elem_bytes()` so cost models and data path stay in byte \
                 agreement across wire formats"
            }
            Lint::L003 => {
                "use `BTreeMap`/`BTreeSet` (or collect + sort before iterating) — wire \
                 payloads, traces and JSON must not depend on hash iteration order"
            }
            Lint::L004 => {
                "keep `obs/schema.rs` key arrays and the `*_json` emitters in lockstep, and \
                 keep `to_json` impls delegating to `obs::schema`"
            }
            Lint::L005 => {
                "user-reachable paths must return `Result`/match instead of `unwrap`/`expect` \
                 — a malformed flag or workload must produce an error, not a panic"
            }
            Lint::L006 => {
                "bind the guard (`let x_span = trace::span(..)`) so the span covers the \
                 region, and only `drop()` spans bound in the same function"
            }
            Lint::L007 => {
                "wall-clock and ambient randomness break deterministic replay — inject time \
                 via the simulated clock / seeded `util::rng::Rng`, or allowlist a genuine \
                 measurement site"
            }
        }
    }
}

/// L002 applies to cost-model and data-path modules — everywhere byte
/// counts feed schedules, reports or wire buffers.
const L002_DIRS: &[&str] = &[
    "rust/src/serve/",
    "rust/src/baselines/",
    "rust/src/obs/",
    "rust/src/comm/",
    "rust/src/cluster/",
    "rust/src/placement/",
    "rust/src/moe/",
    "rust/src/train/",
    "rust/src/backprop/",
    "rust/src/pipeline/",
    "rust/src/layout/",
];

/// L003 applies to modules that construct wire payloads, trace output
/// or JSON (iteration order is observable there).
const L003_PATHS: &[&str] = &["rust/src/comm/", "rust/src/obs/", "rust/src/util/json.rs"];

/// L005 applies to user-reachable code: CLI parsing/dispatch and the
/// serving stack.
const L005_PATHS: &[&str] = &["rust/src/main.rs", "rust/src/cli.rs", "rust/src/serve/"];

fn in_scope(rel: &str, prefixes: &[&str]) -> bool {
    prefixes.iter().any(|p| rel.starts_with(p))
}

pub fn check_file(sf: &SourceFile) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    l001_nan_ordering(sf, &mut out);
    l002_byte_literal(sf, &mut out);
    l003_nondet_iteration(sf, &mut out);
    l005_unwrap_in_cli(sf, &mut out);
    l006_span_balance(sf, &mut out);
    l007_wall_clock(sf, &mut out);
    out
}

/// L001 — `partial_cmp(..).unwrap()` (or `.expect(..)`) is a NaN
/// landmine in float ordering. Applies to test code too: a NaN-unsafe
/// reference sort silently pins the wrong spec.
fn l001_nan_ordering(sf: &SourceFile, out: &mut Vec<Diagnostic>) {
    for i in 0..sf.code.len() {
        let mut from = 0usize;
        while let Some(pos) = sf.code[i][from..].find(".partial_cmp(") {
            let col = from + pos;
            from = col + ".partial_cmp(".len();
            // The statement may wrap; join a few lines (the window
            // starts at line `i`, so `col` indexes into it directly)
            // and cut at the first `;` after the call.
            let window = sf.window(i, 6);
            let tail_full = &window[col..];
            let tail = tail_full.split(';').next().unwrap_or(tail_full);
            let unwrap_at = tail.find(".unwrap()");
            let expect_at = tail.find(".expect(");
            let guard_at = tail.find(".unwrap_or");
            let panic_at = match (unwrap_at, expect_at) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (Some(a), None) => Some(a),
                (None, Some(b)) => Some(b),
                (None, None) => None,
            };
            let bad = match (panic_at, guard_at) {
                (Some(p), Some(g)) => p < g,
                (Some(_), None) => true,
                _ => false,
            };
            if bad {
                out.push(Diagnostic::new(
                    Lint::L001,
                    &sf.rel,
                    i + 1,
                    &sf.raw[i],
                    "NaN-unsafe float ordering: `partial_cmp(..)` chained into a panicking \
                     unwrap/expect"
                        .into(),
                ));
            }
        }
    }
}

/// L002 — a raw `* 4` / `* 4.0` byte factor in a cost-model/data-path
/// module bypasses the canonical element sizes. Suffix-form only: the
/// repo convention keeps byte factors in suffix position and FLOP
/// constants in prefix position (`4.0 * rows * d * h`).
fn l002_byte_literal(sf: &SourceFile, out: &mut Vec<Diagnostic>) {
    if !in_scope(&sf.rel, L002_DIRS) {
        return;
    }
    for i in 0..sf.code.len() {
        if sf.test[i] {
            continue;
        }
        let line = &sf.code[i];
        let chars: Vec<char> = line.chars().collect();
        let n = chars.len();
        let mut flagged = false;
        for s in 0..n {
            if chars[s] != '*' {
                continue;
            }
            let mut j = s + 1;
            while j < n && chars[j] == ' ' {
                j += 1;
            }
            if j >= n || chars[j] != '4' {
                continue;
            }
            let mut end = j + 1;
            if end < n && chars[end] == '.' {
                if end + 1 < n && chars[end + 1] == '0' {
                    end += 2;
                } else {
                    continue; // e.g. `* 4.5`
                }
            }
            let boundary_ok = end >= n
                || !(chars[end].is_ascii_alphanumeric() || chars[end] == '_' || chars[end] == '.');
            if boundary_ok && !flagged {
                out.push(Diagnostic::new(
                    Lint::L002,
                    &sf.rel,
                    i + 1,
                    &sf.raw[i],
                    "raw `* 4`/`* 4.0` byte factor — element sizes must come from \
                     `F32_BYTES`/`elem_bytes()`"
                        .into(),
                ));
                flagged = true; // one diagnostic per line
            }
        }
    }
}

/// L003 — `HashMap`/`HashSet` in wire/trace/JSON-producing modules.
fn l003_nondet_iteration(sf: &SourceFile, out: &mut Vec<Diagnostic>) {
    if !in_scope(&sf.rel, L003_PATHS) {
        return;
    }
    for i in 0..sf.code.len() {
        if sf.test[i] {
            continue;
        }
        for tok in ["HashMap", "HashSet"] {
            if !token_positions(&sf.code[i], tok).is_empty() {
                out.push(Diagnostic::new(
                    Lint::L003,
                    &sf.rel,
                    i + 1,
                    &sf.raw[i],
                    format!(
                        "`{tok}` in a module that produces wire payloads/trace/JSON — \
                         iteration order leaks into output"
                    ),
                ));
            }
        }
    }
}

/// L005 — `unwrap`/`expect` on user-reachable paths.
fn l005_unwrap_in_cli(sf: &SourceFile, out: &mut Vec<Diagnostic>) {
    if !in_scope(&sf.rel, L005_PATHS) {
        return;
    }
    for i in 0..sf.code.len() {
        if sf.test[i] {
            continue;
        }
        let line = &sf.code[i];
        if line.contains(".unwrap()") || line.contains(".expect(") {
            out.push(Diagnostic::new(
                Lint::L005,
                &sf.rel,
                i + 1,
                &sf.raw[i],
                "panicking unwrap/expect on a user-reachable path (CLI/serve)".into(),
            ));
        }
    }
}

/// L006 — trace spans are RAII guards: an unbound call (or `let _ =`)
/// drops immediately and records a zero-width span, and a `drop(x)` of
/// a span never opened in the same function marks the wrong region.
fn l006_span_balance(sf: &SourceFile, out: &mut Vec<Diagnostic>) {
    // Rule A: every `trace::span(` call site is bound to a named guard.
    for i in 0..sf.code.len() {
        if sf.test[i] {
            continue;
        }
        for col in token_positions(&sf.code[i], "trace::span") {
            let before = &sf.code[i][..col];
            let name = binding_name(before);
            match name {
                Some(n) if n != "_" => {}
                _ => {
                    out.push(Diagnostic::new(
                        Lint::L006,
                        &sf.rel,
                        i + 1,
                        &sf.raw[i],
                        "trace span guard not bound to a named variable — it drops (and \
                         ends) immediately"
                            .into(),
                    ));
                }
            }
        }
    }
    // Rule B: `drop(<x>_span)` must reference a span bound in the same
    // function region.
    for (start, end) in fn_regions(&sf.code) {
        let mut bound: Vec<String> = Vec::new();
        for line in &sf.code[start..end] {
            if let Some(col) = line.find("trace::span") {
                if let Some(name) = binding_name(&line[..col]) {
                    bound.push(name);
                }
            }
        }
        for (off, line) in sf.code[start..end].iter().enumerate() {
            if sf.test[start + off] {
                continue;
            }
            let mut from = 0usize;
            while let Some(pos) = line[from..].find("drop(") {
                let at = from + pos;
                let inner: String = line[at + 5..]
                    .chars()
                    .take_while(|&c| c.is_ascii_alphanumeric() || c == '_')
                    .collect();
                from = at + 5;
                if (inner.ends_with("span") || inner.ends_with("_span"))
                    && !bound.iter().any(|b| *b == inner)
                {
                    out.push(Diagnostic::new(
                        Lint::L006,
                        &sf.rel,
                        start + off + 1,
                        &sf.raw[start + off],
                        format!(
                            "`drop({inner})` closes a span that was not opened in this \
                             function"
                        ),
                    ));
                }
            }
        }
    }
}

/// L007 — wall-clock reads and ambient randomness, outside allowlisted
/// measurement sites, break deterministic replay.
fn l007_wall_clock(sf: &SourceFile, out: &mut Vec<Diagnostic>) {
    const BANNED: &[&str] = &[
        "Instant::now",
        "SystemTime::now",
        "thread_rng",
        "from_entropy",
        "rand::random",
        "RandomState",
    ];
    for i in 0..sf.code.len() {
        if sf.test[i] {
            continue;
        }
        for tok in BANNED {
            if !token_positions(&sf.code[i], tok).is_empty() {
                out.push(Diagnostic::new(
                    Lint::L007,
                    &sf.rel,
                    i + 1,
                    &sf.raw[i],
                    format!("`{tok}` outside an allowlisted measurement site"),
                ));
            }
        }
    }
}

/// `let [mut] <name> [: T] = …` binding name from the text preceding a
/// call, if the line is a let-binding.
fn binding_name(before: &str) -> Option<String> {
    let let_at = before.rfind("let ")?;
    let rest = before[let_at + 4..].trim_start();
    let rest = rest.strip_prefix("mut ").unwrap_or(rest).trim_start();
    let name: String = rest
        .chars()
        .take_while(|&c| c.is_ascii_alphanumeric() || c == '_')
        .collect();
    if name.is_empty() && rest.starts_with('_') {
        return Some("_".into());
    }
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

/// Non-nested `fn` regions (line ranges, end exclusive). Nested items
/// merge into the enclosing region, which only makes the drop-check
/// more permissive.
fn fn_regions(code: &[String]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let n = code.len();
    let mut i = 0usize;
    while i < n {
        if token_positions(&code[i], "fn").is_empty() {
            i += 1;
            continue;
        }
        // Find the opening brace (the signature may wrap or the item may
        // be a trait method ending in `;`).
        let mut depth = 0i64;
        let mut opened = false;
        let mut j = i;
        let mut terminated = false;
        while j < n {
            for ch in code[j].chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => depth -= 1,
                    ';' if !opened => terminated = true,
                    _ => {}
                }
            }
            if terminated && !opened {
                break;
            }
            if opened && depth <= 0 {
                break;
            }
            j += 1;
        }
        out.push((i, (j + 1).min(n)));
        i = j + 1;
    }
    out
}
