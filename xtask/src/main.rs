//! `cargo x <task>` — repo-local developer tasks.
//!
//! The only task today is `analysis`: the repo-specific static lints
//! described in DESIGN.md §16. Exit status is the contract CI relies
//! on: 0 for a clean tree, 1 when violations (or stale allowlist
//! entries) exist, 2 for usage errors.

use std::env;
use std::path::PathBuf;
use std::process::ExitCode;

use xtask::engine;

const USAGE: &str = "\
usage: cargo x analysis [--json] [--fix-hints] [--root <dir>] [--allow <file>]

  --json        emit the report as JSON on stdout (for CI artifacts)
  --fix-hints   print per-lint remediation guidance under each finding
  --root DIR    repo root to scan (default: the workspace root)
  --allow FILE  allowlist file (default: <root>/xtask/analysis.allow)
";

fn main() -> ExitCode {
    let mut args = env::args().skip(1);
    let Some(task) = args.next() else {
        eprint!("{USAGE}");
        return ExitCode::from(2);
    };
    if task != "analysis" {
        eprintln!("unknown task '{task}'");
        eprint!("{USAGE}");
        return ExitCode::from(2);
    }

    let mut json = false;
    let mut fix_hints = false;
    let mut root: Option<PathBuf> = None;
    let mut allow: Option<PathBuf> = None;
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--json" => json = true,
            "--fix-hints" => fix_hints = true,
            "--root" => match args.next() {
                Some(v) => root = Some(PathBuf::from(v)),
                None => return usage_err("--root requires a directory"),
            },
            "--allow" => match args.next() {
                Some(v) => allow = Some(PathBuf::from(v)),
                None => return usage_err("--allow requires a file"),
            },
            other => return usage_err(&format!("unknown flag '{other}'")),
        }
    }

    // The xtask crate lives at <root>/xtask, so the workspace root is
    // one level up from our manifest.
    let root = root.unwrap_or_else(|| {
        PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("xtask manifest has a parent")
            .to_path_buf()
    });
    let allow = allow.unwrap_or_else(|| root.join("xtask").join("analysis.allow"));

    let report = match engine::run(&root, &allow) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("analysis failed: {e}");
            return ExitCode::from(2);
        }
    };
    if json {
        println!("{}", report.to_json());
    } else {
        report.print_human(fix_hints);
    }
    if report.clean() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}

fn usage_err(msg: &str) -> ExitCode {
    eprintln!("{msg}");
    eprint!("{USAGE}");
    ExitCode::from(2)
}
