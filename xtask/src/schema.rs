//! L004 — schema pinning: cross-parse the key arrays in
//! `rust/src/obs/schema.rs` against the `*_json` emitter bodies, and
//! verify the external `to_json` impls delegate to `obs::schema`.
//!
//! Drift in either direction (a pinned key the emitter no longer
//! writes, or an emitted key missing from the pin) is a violation, as
//! is a consumer module hand-rolling its own JSON instead of
//! delegating. String literals are parsed from the raw source — an
//! emitter key is a literal followed by `,` or `.into()`, and a literal
//! that is the first argument of `quantile_fields(` expands to the
//! `_p50`/`_p95`/`_p99` triple.

use std::fs;
use std::path::Path;

use crate::engine::Diagnostic;
use crate::lints::Lint;

const SCHEMA_PATH: &str = "rust/src/obs/schema.rs";

/// (key array, emitter fn) pairs pinned against each other.
const PINS: &[(&str, &str)] = &[
    ("BREAKDOWN_KEYS", "breakdown_json"),
    ("SLO_KEYS", "slo_json"),
    ("BENCH_RESULT_KEYS", "bench_result_json"),
];

/// (consumer file, required delegation call) — the `to_json` body in
/// each file must route through the named schema emitter.
const DELEGATES: &[(&str, &str)] = &[
    ("rust/src/coordinator/metrics.rs", "schema::breakdown_json"),
    ("rust/src/serve/slo.rs", "schema::slo_json"),
    ("rust/src/benchkit.rs", "schema::bench_result_json"),
];

/// Run the schema check against `root`. A repo without
/// `rust/src/obs/schema.rs` (fixture trees for the other lints) is
/// skipped entirely.
pub fn check(root: &Path) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let schema_file = root.join(SCHEMA_PATH);
    let Ok(text) = fs::read_to_string(&schema_file) else {
        return out;
    };
    for &(array, emitter) in PINS {
        let before = out.len();
        let Some((pinned, array_line)) = parse_key_array(&text, array) else {
            out.push(Diagnostic::new(
                Lint::L004,
                SCHEMA_PATH,
                1,
                "",
                format!("pinned key array `{array}` not found"),
            ));
            continue;
        };
        let Some((emitted, fn_line)) = parse_emitted_keys(&text, emitter) else {
            out.push(Diagnostic::new(
                Lint::L004,
                SCHEMA_PATH,
                array_line,
                "",
                format!("emitter `{emitter}` not found for `{array}`"),
            ));
            continue;
        };
        for k in &pinned {
            if !emitted.contains(k) {
                out.push(Diagnostic::new(
                    Lint::L004,
                    SCHEMA_PATH,
                    array_line,
                    "",
                    format!("`{array}` pins key \"{k}\" but `{emitter}` does not emit it"),
                ));
            }
        }
        for k in &emitted {
            if !pinned.contains(k) {
                out.push(Diagnostic::new(
                    Lint::L004,
                    SCHEMA_PATH,
                    fn_line,
                    "",
                    format!("`{emitter}` emits key \"{k}\" missing from `{array}`"),
                ));
            }
        }
        if out.len() == before && pinned != emitted {
            out.push(Diagnostic::new(
                Lint::L004,
                SCHEMA_PATH,
                array_line,
                "",
                format!("`{array}` and `{emitter}` carry the same keys in different order"),
            ));
        }
    }
    for &(file, call) in DELEGATES {
        let path = root.join(file);
        let Ok(src) = fs::read_to_string(&path) else {
            continue;
        };
        if let Some(at) = src.find("fn to_json") {
            let line = src[..at].matches('\n').count() + 1;
            let body = body_after(&src, at);
            if !body.contains(call) {
                out.push(Diagnostic::new(
                    Lint::L004,
                    file,
                    line,
                    "",
                    format!("`to_json` does not delegate to `{call}` — schema can drift"),
                ));
            }
        }
    }
    out
}

/// Collect the string literals of `pub const NAME: &[&str] = &[ … ];`.
/// Returns the keys and the 1-based line of the declaration.
fn parse_key_array(text: &str, name: &str) -> Option<(Vec<String>, usize)> {
    let decl = format!("const {name}");
    let at = text.find(&decl)?;
    let line = text[..at].matches('\n').count() + 1;
    let tail = &text[at..];
    let end = tail.find("];")?;
    Some((string_literals(&tail[..end]).into_iter().map(|(s, _)| s).collect(), line))
}

/// Collect the keys a `fn <name>` emitter writes, in source order.
fn parse_emitted_keys(text: &str, name: &str) -> Option<(Vec<String>, usize)> {
    let decl = format!("fn {name}");
    let at = text.find(&decl)?;
    let line = text[..at].matches('\n').count() + 1;
    let body = body_after(text, at);
    let mut keys = Vec::new();
    for (lit, pos) in string_literals(body) {
        if preceded_by_call(body, pos, "quantile_fields") {
            for suffix in ["_p50", "_p95", "_p99"] {
                keys.push(format!("{lit}{suffix}"));
            }
            continue;
        }
        let after = body[pos..]
            .find('"')
            .and_then(|open| {
                let close = find_close_quote(&body[pos + open + 1..])?;
                Some(body[pos + open + 1 + close + 1..].trim_start())
            })
            .unwrap_or("");
        if after.starts_with(".into()") || after.starts_with(',') {
            keys.push(lit);
        }
    }
    Some((keys, line))
}

/// The brace-delimited body starting at the first `{` after `at`.
fn body_after(text: &str, at: usize) -> &str {
    let bytes = text.as_bytes();
    let mut i = at;
    while i < bytes.len() && bytes[i] != b'{' {
        i += 1;
    }
    let start = i;
    let mut depth = 0i64;
    while i < bytes.len() {
        match bytes[i] {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return &text[start..=i];
                }
            }
            _ => {}
        }
        i += 1;
    }
    &text[start..]
}

/// `(literal, byte offset of the opening quote)` for every plain `"…"`
/// literal in `text` (escapes handled; raw strings don't appear in the
/// schema module).
fn string_literals(text: &str) -> Vec<(String, usize)> {
    let b: Vec<char> = text.chars().collect();
    // Byte offsets need a parallel index because chars vary in width.
    let mut out = Vec::new();
    let mut byte = 0usize;
    let mut i = 0usize;
    while i < b.len() {
        if b[i] == '"' {
            let open_byte = byte;
            byte += 1;
            i += 1;
            let mut lit = String::new();
            while i < b.len() && b[i] != '"' {
                if b[i] == '\\' && i + 1 < b.len() {
                    lit.push(b[i + 1]);
                    byte += b[i].len_utf8() + b[i + 1].len_utf8();
                    i += 2;
                    continue;
                }
                lit.push(b[i]);
                byte += b[i].len_utf8();
                i += 1;
            }
            if i < b.len() {
                byte += 1;
                i += 1; // closing quote
            }
            out.push((lit, open_byte));
        } else {
            byte += b[i].len_utf8();
            i += 1;
        }
    }
    out
}

/// Byte offset of the closing quote of a literal whose contents start
/// at the beginning of `s`.
fn find_close_quote(s: &str) -> Option<usize> {
    let b = s.as_bytes();
    let mut i = 0usize;
    while i < b.len() {
        match b[i] {
            b'\\' => i += 2,
            b'"' => return Some(i),
            _ => i += 1,
        }
    }
    None
}

/// Whether the literal at `pos` is the first argument of `call(`.
fn preceded_by_call(text: &str, pos: usize, call: &str) -> bool {
    let before = text[..pos].trim_end();
    let Some(stripped) = before.strip_suffix('(') else {
        return false;
    };
    stripped.trim_end().ends_with(call)
}
