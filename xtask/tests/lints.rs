//! Integration tests for `cargo x analysis`: per-lint good/bad
//! fixtures, allowlist round-trips, and a self-check that the shipped
//! tree is clean under the repo allowlist.

use std::fs;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

use xtask::engine::{self, Report};
use xtask::lints::Lint;

static COUNTER: AtomicUsize = AtomicUsize::new(0);

/// A throwaway fixture repo under the system temp dir; removed on drop.
struct Fixture {
    root: PathBuf,
}

impl Fixture {
    fn new() -> Fixture {
        let id = COUNTER.fetch_add(1, Ordering::SeqCst);
        let root = std::env::temp_dir()
            .join(format!("xtask-fixture-{}-{id}", std::process::id()));
        fs::create_dir_all(root.join("rust/src")).expect("mkdir fixture");
        Fixture { root }
    }

    /// Write `text` at `rel` (repo-relative, forward slashes).
    fn file(&self, rel: &str, text: &str) -> &Fixture {
        let path = self.root.join(rel);
        fs::create_dir_all(path.parent().expect("has parent")).expect("mkdir");
        fs::write(path, text).expect("write fixture file");
        self
    }

    fn allow(&self, text: &str) -> PathBuf {
        let path = self.root.join("analysis.allow");
        fs::write(&path, text).expect("write allowlist");
        path
    }

    fn run(&self) -> Report {
        engine::run(&self.root, &self.root.join("analysis.allow")).expect("run")
    }
}

impl Drop for Fixture {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.root);
    }
}

fn ids(report: &Report) -> Vec<&'static str> {
    report.violations.iter().map(|d| d.lint.id()).collect()
}

// --- L001: NaN-unsafe float ordering ---

#[test]
fn l001_flags_partial_cmp_unwrap() {
    let fx = Fixture::new();
    fx.file(
        "rust/src/sort.rs",
        "pub fn worst(v: &mut [f32]) {\n    v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n",
    );
    let r = fx.run();
    assert_eq!(ids(&r), ["L001"]);
    assert_eq!(r.violations[0].line, 2);
}

#[test]
fn l001_flags_wrapped_statement_and_test_code() {
    let fx = Fixture::new();
    fx.file(
        "rust/src/sort.rs",
        "#[cfg(test)]\nmod tests {\n    fn reference(v: &mut [f32]) {\n        v.sort_by(|a, b| {\n            a.partial_cmp(b)\n                .unwrap()\n        });\n    }\n}\n",
    );
    // L001 deliberately covers test code: a NaN-unsafe reference sort
    // pins the wrong spec.
    assert_eq!(ids(&fx.run()), ["L001"]);
}

#[test]
fn l001_accepts_total_cmp_and_guarded_unwrap_or() {
    let fx = Fixture::new();
    fx.file(
        "rust/src/sort.rs",
        "use std::cmp::Ordering;\npub fn good(v: &mut [f32]) {\n    v.sort_by(|a, b| a.total_cmp(b));\n    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(Ordering::Equal));\n}\n",
    );
    assert!(ids(&fx.run()).is_empty());
}

// --- L002: raw byte-size literals ---

#[test]
fn l002_flags_suffix_byte_factor_in_scope() {
    let fx = Fixture::new();
    fx.file(
        "rust/src/serve/cost.rs",
        "pub fn bytes(t: usize, d: usize) -> usize {\n    t * d * 4\n}\npub fn bytes_f(t: f64) -> f64 {\n    t * 4.0\n}\n",
    );
    assert_eq!(ids(&fx.run()), ["L002", "L002"]);
}

#[test]
fn l002_ignores_prefix_flop_constants_and_out_of_scope() {
    let fx = Fixture::new();
    fx.file(
        "rust/src/serve/cost.rs",
        "pub fn flops(r: f64, d: f64, h: f64) -> f64 {\n    4.0 * r * d * h\n}\n",
    );
    // Same pattern outside the cost-model/data-path scope: not flagged.
    fx.file("rust/src/misc.rs", "pub fn x(n: usize) -> usize {\n    n * 4\n}\n");
    assert!(ids(&fx.run()).is_empty());
}

// --- L003: nondeterministic iteration ---

#[test]
fn l003_flags_hash_collections_in_wire_modules() {
    let fx = Fixture::new();
    fx.file(
        "rust/src/comm/wire.rs",
        "use std::collections::HashMap;\npub fn payload() -> HashMap<u32, u32> {\n    HashMap::new()\n}\n",
    );
    let r = fx.run();
    assert_eq!(ids(&r), ["L003", "L003", "L003"]);
}

#[test]
fn l003_accepts_btree_and_out_of_scope_hash() {
    let fx = Fixture::new();
    fx.file(
        "rust/src/comm/wire.rs",
        "use std::collections::BTreeMap;\npub fn payload() -> BTreeMap<u32, u32> {\n    BTreeMap::new()\n}\n",
    );
    fx.file(
        "rust/src/gating/cache.rs",
        "use std::collections::HashMap;\npub type Cache = HashMap<u64, usize>;\n",
    );
    assert!(ids(&fx.run()).is_empty());
}

// --- L004: schema pinning ---

const SCHEMA_OK: &str = r#"
pub const BREAKDOWN_KEYS: &[&str] = &["alpha", "beta"];
pub const SLO_KEYS: &[&str] = &["duration"];
pub const BENCH_RESULT_KEYS: &[&str] = &["name"];
pub fn breakdown_json() {
    let fields = vec![("alpha", 1.0), ("beta", 2.0)];
}
pub fn slo_json() {
    let fields = vec![("duration".into(), 0.0)];
}
pub fn bench_result_json() {
    let fields = vec![("name", 0.0)];
}
"#;

#[test]
fn l004_clean_when_arrays_match_emitters() {
    let fx = Fixture::new();
    fx.file("rust/src/obs/schema.rs", SCHEMA_OK);
    assert!(ids(&fx.run()).is_empty());
}

#[test]
fn l004_flags_drift_in_both_directions() {
    let fx = Fixture::new();
    // "beta" pinned but not emitted; "gamma" emitted but not pinned.
    fx.file(
        "rust/src/obs/schema.rs",
        r#"
pub const BREAKDOWN_KEYS: &[&str] = &["alpha", "beta"];
pub const SLO_KEYS: &[&str] = &["duration"];
pub const BENCH_RESULT_KEYS: &[&str] = &["name"];
pub fn breakdown_json() {
    let fields = vec![("alpha", 1.0), ("gamma", 2.0)];
}
pub fn slo_json() {
    let fields = vec![("duration".into(), 0.0)];
}
pub fn bench_result_json() {
    let fields = vec![("name", 0.0)];
}
"#,
    );
    let r = fx.run();
    assert_eq!(ids(&r), ["L004", "L004"]);
    assert!(r.violations.iter().any(|d| d.message.contains("\"beta\"")));
    assert!(r.violations.iter().any(|d| d.message.contains("\"gamma\"")));
}

#[test]
fn l004_expands_quantile_fields_and_checks_delegation() {
    let fx = Fixture::new();
    fx.file(
        "rust/src/obs/schema.rs",
        r#"
pub const BREAKDOWN_KEYS: &[&str] = &["alpha"];
pub const SLO_KEYS: &[&str] =
    &["latency_p50", "latency_p95", "latency_p99"];
pub const BENCH_RESULT_KEYS: &[&str] = &["name"];
pub fn breakdown_json() {
    let fields = vec![("alpha", 1.0)];
}
pub fn slo_json() {
    let mut fields = vec![];
    fields.extend(quantile_fields("latency", &q));
}
pub fn bench_result_json() {
    let fields = vec![("name", 0.0)];
}
"#,
    );
    // A consumer hand-rolling its own JSON instead of delegating.
    fx.file(
        "rust/src/coordinator/metrics.rs",
        "impl B {\n    pub fn to_json(&self) -> String {\n        String::new()\n    }\n}\n",
    );
    let r = fx.run();
    assert_eq!(ids(&r), ["L004"]);
    assert!(r.violations[0].path.ends_with("coordinator/metrics.rs"));
    assert!(r.violations[0].message.contains("schema::breakdown_json"));
}

// --- L005: unwrap on user-reachable paths ---

#[test]
fn l005_flags_unwrap_in_cli_and_serve() {
    let fx = Fixture::new();
    fx.file(
        "rust/src/cli.rs",
        "pub fn parse(s: &str) -> usize {\n    s.parse().unwrap()\n}\n",
    );
    fx.file(
        "rust/src/serve/engine.rs",
        "pub fn shard(v: &[u32]) -> u32 {\n    *v.first().expect(\"nonempty\")\n}\n",
    );
    assert_eq!(ids(&fx.run()), ["L005", "L005"]);
}

#[test]
fn l005_skips_tests_and_out_of_scope() {
    let fx = Fixture::new();
    fx.file(
        "rust/src/cli.rs",
        "pub fn parse(s: &str) -> Option<usize> {\n    s.parse().ok()\n}\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        super::parse(\"3\").unwrap();\n    }\n}\n",
    );
    fx.file("rust/src/train/opt.rs", "pub fn f(v: Option<u32>) -> u32 {\n    v.unwrap()\n}\n");
    assert!(ids(&fx.run()).is_empty());
}

// --- L006: span balance ---

#[test]
fn l006_flags_unbound_span_and_foreign_drop() {
    let fx = Fixture::new();
    fx.file(
        "rust/src/step.rs",
        "pub fn step() {\n    trace::span(\"gate\");\n    let _ = trace::span(\"layout\");\n}\npub fn other() {\n    drop(gate_span);\n}\n",
    );
    let r = fx.run();
    assert_eq!(ids(&r), ["L006", "L006", "L006"]);
}

#[test]
fn l006_accepts_bound_guard_dropped_in_same_fn() {
    let fx = Fixture::new();
    fx.file(
        "rust/src/step.rs",
        "pub fn step() {\n    let gate_span = trace::span(\"gate\");\n    work();\n    drop(gate_span);\n    let _whole_span = trace::span(\"rest\");\n}\n",
    );
    assert!(ids(&fx.run()).is_empty());
}

// --- L007: wall-clock / ambient randomness ban ---

#[test]
fn l007_flags_wall_clock_outside_allowlist() {
    let fx = Fixture::new();
    fx.file(
        "rust/src/gating/timer.rs",
        "use std::time::Instant;\npub fn now_ms() -> u128 {\n    Instant::now().elapsed().as_millis()\n}\n",
    );
    let r = fx.run();
    assert_eq!(ids(&r), ["L007"]);
    assert_eq!(r.violations[0].line, 3);
}

#[test]
fn l007_allowlist_round_trip() {
    let fx = Fixture::new();
    fx.file(
        "rust/src/bench.rs",
        "use std::time::Instant;\npub fn measure() -> f64 {\n    let t0 = Instant::now();\n    t0.elapsed().as_secs_f64()\n}\n",
    );
    // Without an allowlist: one violation.
    assert_eq!(ids(&fx.run()), ["L007"]);
    // With the matching entry: suppressed and counted as used.
    fx.allow("L007 bench.rs Instant::now\n");
    let r = fx.run();
    assert!(ids(&r).is_empty());
    assert_eq!(r.allowed.len(), 1);
    assert!(r.unused_allow.is_empty());
    assert!(r.clean());
}

#[test]
fn stale_allowlist_entry_fails_the_run() {
    let fx = Fixture::new();
    fx.file("rust/src/ok.rs", "pub fn ok() {}\n");
    fx.allow("L007 gone.rs Instant::now\n");
    let r = fx.run();
    assert!(r.violations.is_empty());
    assert_eq!(r.unused_allow.len(), 1);
    assert!(!r.clean(), "stale entries must fail the gate");
}

#[test]
fn malformed_allowlist_is_an_error() {
    let fx = Fixture::new();
    fx.file("rust/src/ok.rs", "pub fn ok() {}\n");
    let bad = fx.allow("L099 foo.rs pattern\n");
    assert!(engine::run(&fx.root, &bad).is_err(), "unknown lint id must error");
    let bad2 = fx.allow("L007 only-two-tokens\n");
    assert!(engine::run(&fx.root, &bad2).is_err(), "missing pattern must error");
}

#[test]
fn inline_allow_marker_suppresses() {
    let fx = Fixture::new();
    fx.file(
        "rust/src/gating/timer.rs",
        "use std::time::Instant;\npub fn a() -> Instant {\n    // lint:allow(L007) — epoch base for relative stamps\n    Instant::now()\n}\npub fn b() -> Instant {\n    Instant::now() // lint:allow(L007)\n}\n",
    );
    let r = fx.run();
    assert!(ids(&r).is_empty());
    assert_eq!(r.allowed.len(), 2);
}

// --- report plumbing ---

#[test]
fn json_report_is_well_formed() {
    let fx = Fixture::new();
    fx.file(
        "rust/src/cli.rs",
        "pub fn parse(s: &str) -> usize {\n    s.parse().unwrap()\n}\n",
    );
    let j = fx.run().to_json();
    assert!(j.contains("\"clean\":false"));
    assert!(j.contains("\"lint\":\"L005\""));
    assert!(j.contains("\"path\":\"rust/src/cli.rs\""));
    // Escaping: the snippet contains a quoted string.
    fx.file("rust/src/cli.rs", "pub fn p(s: &str) -> usize {\n    s.parse().expect(\"a \\\"b\\\"\")\n}\n");
    let j2 = fx.run().to_json();
    assert!(j2.contains("\\\""), "quotes in snippets must be escaped");
}

#[test]
fn diagnostics_are_sorted_and_carry_lint_ids() {
    let fx = Fixture::new();
    fx.file(
        "rust/src/comm/wire.rs",
        "use std::collections::HashSet;\npub fn z(v: &mut [f32]) {\n    v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n",
    );
    let r = fx.run();
    assert_eq!(ids(&r), ["L003", "L001"], "sorted by (path, line, lint)");
    for d in &r.violations {
        assert!(Lint::from_id(d.lint.id()).is_some());
        assert!(d.line >= 1);
    }
}

// --- the shipped tree itself ---

#[test]
fn shipped_tree_is_clean_under_repo_allowlist() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("workspace root")
        .to_path_buf();
    let allow = root.join("xtask").join("analysis.allow");
    let r = engine::run(&root, &allow).expect("run on shipped tree");
    assert!(
        r.violations.is_empty(),
        "shipped tree has violations:\n{}",
        r.violations
            .iter()
            .map(|d| format!("{}:{} [{}] {}", d.path, d.line, d.lint.id(), d.message))
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(
        r.unused_allow.is_empty(),
        "stale allowlist entries:\n{}",
        r.unused_allow.join("\n")
    );
    assert!(r.files_scanned > 50, "expected the full tree, scanned {}", r.files_scanned);
}
